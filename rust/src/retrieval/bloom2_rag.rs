//! Improved Bloom Filter T-RAG — "BF2" (paper §4.1): same pruned descent
//! as BF T-RAG, but Bloom checks are *skipped at nodes just above the
//! leaf level*. For a near-leaf node, querying k hash positions costs
//! more than directly comparing its handful of leaf children, so the
//! filter consultation is pure overhead there — the paper's observed
//! extra speedup over plain BF T-RAG.

use std::sync::Arc;

use crate::filter::fingerprint::entity_key;
use crate::filter::tree_bloom::BloomForest;
use crate::forest::{EntityAddress, Forest, NodeIdx};
use crate::retrieval::{Retriever, SharedRetriever};

/// BF2 retriever: Bloom-pruned descent with near-leaf check skipping.
pub struct Bloom2TRag {
    forest: Arc<Forest>,
    blooms: BloomForest,
    /// `heights[tree][node]`: node height (leaf = 0).
    heights: Vec<Vec<u8>>,
    fp_rate: f64,
    bytes: usize,
}

impl Bloom2TRag {
    /// Build blooms + height table.
    pub fn new(forest: Arc<Forest>, fp_rate: f64) -> Self {
        let blooms = BloomForest::build(&forest, fp_rate);
        let heights = forest
            .trees()
            .iter()
            .map(|tree| {
                let n = tree.len();
                let mut h = vec![0u8; n];
                // children have larger indices: reverse pass is bottom-up
                for idx in (0..n).rev() {
                    let node = tree.node(idx as NodeIdx);
                    for &c in &node.children {
                        h[idx] = h[idx].max(h[c as usize].saturating_add(1));
                    }
                }
                h
            })
            .collect::<Vec<_>>();
        let bytes = blooms.memory_bytes()
            + heights.iter().map(Vec::len).sum::<usize>();
        Bloom2TRag { forest, blooms, heights, fp_rate, bytes }
    }

    fn descend(
        &self,
        tree_idx: u32,
        node: NodeIdx,
        id: crate::forest::EntityId,
        key: u64,
        out: &mut Vec<EntityAddress>,
    ) {
        let tree = self.forest.tree(tree_idx);
        if tree.entity(node) == id {
            out.push(EntityAddress::new(tree_idx, node));
        }
        let near_leaf = self.heights[tree_idx as usize][node as usize] <= 1;
        for &c in &tree.node(node).children {
            if near_leaf {
                // children are leaves: compare directly, skip the filter
                if tree.entity(c) == id {
                    out.push(EntityAddress::new(tree_idx, c));
                }
            } else if self.blooms.might_contain(tree_idx, c, key) {
                self.descend(tree_idx, c, id, key, out);
            }
        }
    }
}

impl SharedRetriever for Bloom2TRag {
    fn name(&self) -> &'static str {
        "BF2 T-RAG"
    }

    /// Lock-free read path: blooms and the height table are immutable
    /// after build (shared across threads via `ArcRetriever`).
    fn find_shared(&self, entity: &str, out: &mut Vec<EntityAddress>) {
        let Some(id) = self.forest.entity_id(entity) else {
            return;
        };
        let key = entity_key(entity);
        for t in 0..self.forest.len() as u32 {
            if self.blooms.might_contain(t, 0, key) {
                self.descend(t, 0, id, key, out);
            }
        }
    }

    fn rebuild(&self, forest: Arc<Forest>) -> Self {
        Self::new(forest, self.fp_rate)
    }

    fn index_bytes(&self) -> usize {
        self.bytes
    }
}

impl Retriever for Bloom2TRag {
    fn name(&self) -> &'static str {
        SharedRetriever::name(self)
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        let mut out = Vec::new();
        self.find_shared(entity, &mut out);
        out
    }

    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        self.find_shared(entity, out);
    }

    fn reindex(&mut self, forest: Arc<Forest>, _new_trees: &[u32]) {
        // blooms + height table are whole-forest: rebuild
        *self = self.rebuild(forest);
    }

    fn index_bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let ids: Vec<_> = ["r", "mid", "leaf1", "leaf2", "deep", "deeper"]
            .iter()
            .map(|n| f.intern(n))
            .collect();
        let mut t = Tree::with_root(ids[0]);
        let m = t.add_child(0, ids[1]);
        t.add_child(m, ids[2]);
        t.add_child(m, ids[3]);
        let d = t.add_child(0, ids[4]);
        t.add_child(d, ids[5]);
        f.add_tree(t);
        Arc::new(f)
    }

    #[test]
    fn agrees_with_scan_including_leaves() {
        let f = forest();
        let mut r = Bloom2TRag::new(f.clone(), 0.01);
        for name in ["r", "mid", "leaf1", "leaf2", "deep", "deeper", "none"] {
            let want = f
                .entity_id(name)
                .map(|id| f.scan_addresses(id))
                .unwrap_or_default();
            assert_eq!(r.find(name), want, "{name}");
        }
    }

    #[test]
    fn heights_computed() {
        let f = forest();
        let r = Bloom2TRag::new(f, 0.01);
        assert_eq!(r.heights[0][0], 2, "root height");
        assert_eq!(r.heights[0][2], 0, "leaf height");
    }
}
