//! Synthetic UNHCR-style organizational chart — stand-in for the T-RAG
//! paper's org-chart dataset (§4.3, "the English dataset of the UNHCR
//! organizational chart"). Pre-segmented into entities (no raw-text
//! path): headquarters -> divisions -> regional bureaus -> field teams.

use crate::data::vocab::{ORG_DIVISIONS, ORG_REGIONS, ORG_TEAMS};
use crate::forest::{builder::build_trees, Forest};
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Clone, Copy, Debug)]
pub struct OrgChartConfig {
    /// Number of organizations (= trees).
    pub trees: usize,
    /// Divisions per organization.
    pub divisions: usize,
    /// Bureaus per division.
    pub bureaus: usize,
    /// Teams per bureau.
    pub teams: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for OrgChartConfig {
    fn default() -> Self {
        OrgChartConfig { trees: 10, divisions: 6, bureaus: 3, teams: 4, seed: 0x0A61 }
    }
}

/// The generated dataset: relation groups per organization.
#[derive(Clone, Debug)]
pub struct OrgChartDataset {
    pub orgs: Vec<(String, Vec<(String, String)>)>,
}

impl OrgChartDataset {
    /// Generate deterministically.
    pub fn generate(cfg: OrgChartConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut orgs = Vec::with_capacity(cfg.trees);
        for i in 0..cfg.trees {
            let root = format!("organization {i}");
            let mut rels = Vec::new();
            let ndiv = rng.range(cfg.divisions / 2 + 1, cfg.divisions + 2);
            for d in 0..ndiv {
                // shared division names across orgs (cross-tree entities)
                let div = ORG_DIVISIONS[d % ORG_DIVISIONS.len()].to_string();
                rels.push((div.clone(), root.clone()));
                let nbur = rng.range(1, cfg.bureaus + 1);
                for b in 0..nbur {
                    let bureau = format!(
                        "{} {}",
                        ORG_REGIONS[(d + b) % ORG_REGIONS.len()],
                        div.split_whitespace().next().unwrap()
                    );
                    rels.push((bureau.clone(), div.clone()));
                    let nteam = rng.range(1, cfg.teams + 1);
                    for t in 0..nteam {
                        let team = format!(
                            "{} {} {}",
                            bureau.split_whitespace().next().unwrap(),
                            ORG_TEAMS[(b + t) % ORG_TEAMS.len()],
                            t
                        );
                        rels.push((team, bureau.clone()));
                    }
                }
            }
            orgs.push((root, rels));
        }
        OrgChartDataset { orgs }
    }

    /// Build the forest.
    pub fn build_forest(&self) -> Forest {
        let mut forest = Forest::new();
        for (_, rels) in &self.orgs {
            build_trees(&mut forest, rels);
        }
        forest
    }

    /// Summary documents (vector-search corpus): one per organization.
    pub fn documents(&self) -> Vec<String> {
        self.orgs
            .iter()
            .map(|(root, rels)| {
                let mut doc = format!("{root} structure overview.");
                for (c, p) in rels.iter().take(40) {
                    doc.push_str(&format!(" The {c} reports to {p}."));
                }
                doc
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let a = OrgChartDataset::generate(OrgChartConfig::default());
        let b = OrgChartDataset::generate(OrgChartConfig::default());
        assert_eq!(a.orgs.len(), 10);
        assert_eq!(a.orgs[3].1, b.orgs[3].1);
    }

    #[test]
    fn forest_depth_is_three_plus() {
        let f = OrgChartDataset::generate(OrgChartConfig::default()).build_forest();
        assert!(f.stats().max_depth >= 3);
        assert_eq!(f.len(), 10);
    }

    #[test]
    fn divisions_shared_across_orgs() {
        let f = OrgChartDataset::generate(OrgChartConfig::default()).build_forest();
        let id = f.entity_id("protection division").expect("exists");
        assert!(f.scan_addresses(id).len() >= 5, "shared across trees");
    }

    #[test]
    fn documents_mention_structure() {
        let ds = OrgChartDataset::generate(OrgChartConfig::default());
        let docs = ds.documents();
        assert_eq!(docs.len(), 10);
        assert!(docs[0].contains("reports to"));
    }
}
