//! Sharded Cuckoo Filter: the key space partitioned across N independent
//! [`CuckooFilter`] shards so retrieval scales with reader threads.
//!
//! # Design
//!
//! Each shard owns a full filter — buckets, temperatures, block arena —
//! behind its own [`std::sync::RwLock`]. A key's shard is chosen by the
//! *high* bits of the secondary hash ([`shard_index`]), independent of
//! the bits that pick the in-shard bucket and the fingerprint, so load
//! spreads uniformly and shards never need to coordinate: an operation
//! touches exactly one shard. The configured `initial_buckets` is split
//! across shards with *ceiling* division, so total capacity is never
//! below what was configured.
//!
//! # Locking invariants
//!
//! * **Lookups take only the shard read lock.** The underlying filter's
//!   [`CuckooFilter::lookup_shared`] works through `&self`: temperature
//!   bumps are relaxed `AtomicU32` increments and dirty-bucket flags
//!   relaxed `AtomicBool` stores, so any number of readers proceed in
//!   parallel (per shard and across shards). This holds **during
//!   expansion too**: a shard mid-doubling serves reads from both table
//!   generations through the same read lock.
//! * **Structural mutations take the shard write lock, but only for
//!   bounded holds.** Insert, delete and push_address each do one
//!   key's work plus at most one migration step
//!   ([`CuckooConfig::migration_step_buckets`] buckets). A shard
//!   expansion is *never* executed as one long write-locked rebuild:
//!   the doubled table is built aside and live entries migrate
//!   range-by-range, so a reader arriving mid-growth waits for at most
//!   one step, not a full-table migration. A write lock on one shard
//!   never blocks readers of another.
//! * **Maintenance never holds a write lock across the shard.**
//!   [`maintain`](ShardedCuckooFilter::maintain) first drains any
//!   pending migration one step per write-lock acquisition, then runs
//!   the temperature re-sort epoch-style: dirty buckets are snapshotted
//!   and their sorted orders computed under a *read* lock
//!   ([`CuckooFilter::plan_maintenance`]), and each rebuilt bucket is
//!   swapped in under a short write lock that validates the bucket is
//!   structurally unchanged ([`CuckooFilter::apply_bucket_plan`]); a
//!   bucket that changed in between simply stays dirty for the next
//!   round. Readers therefore interleave with maintenance at bucket
//!   granularity.
//! * **Readers help migrations finish, without ever blocking.** After a
//!   lookup observes a pending migration, it opportunistically
//!   `try_write`s one bounded step; if the lock is contended the attempt
//!   is abandoned — whoever holds it is making progress already.
//! * **Block-list reads happen under the same read-lock hold** as the
//!   lookup that produced the head — addresses are copied out before the
//!   guard drops, so a concurrent delete/expand on the shard can never
//!   invalidate a head the caller still holds.
//! * Lock poisoning (a writer panicking mid-mutation) propagates to all
//!   later accessors via `unwrap`, which is the safe failure mode: the
//!   shard's invariants can no longer be trusted.
//!
//! Aggregate accessors (`len`, `stats`, `memory_bytes`) lock shards one
//! at a time; they are monitoring APIs and make no cross-shard atomicity
//! promise.
//!
//! The same partition-by-key idea repeats one level up: the router's
//! [`ShardRing`](crate::router::ring::ShardRing) splits the key space
//! across *processes* with an independent slice of the same hash family
//! ([`rendezvous_score`](crate::filter::fingerprint::rendezvous_score)),
//! and a [`KeyPartition`](crate::rag::config::KeyPartition) restricts a
//! backend's filter to its owned keys — so in-process shards and
//! cross-process replicas compose without correlation.

use crate::sync::RwLock;

use crate::filter::cuckoo::{
    CuckooConfig, CuckooFilter, CuckooStats, KICK_DEPTH_BUCKETS,
};
use crate::filter::fingerprint::shard_index;
use crate::forest::EntityAddress;
use crate::util::json::Json;

/// Planned bucket swaps applied per write-lock acquisition during
/// [`ShardedCuckooFilter::maintain`] — the bound on a maintenance hold.
const MAINTAIN_SWAP_BATCH: usize = 32;

/// One-shot snapshot of the filter's internals for the observability
/// plane: occupancy, probe work, displacement pressure, migration
/// progress, memory footprint and the analytic false-positive estimate.
/// Produced by [`ShardedCuckooFilter::telemetry`], surfaced through the
/// coordinator's `\x01stats` payload (under `"filter"`) and the
/// `\x01metrics` Prometheus exposition.
#[derive(Clone, Debug)]
pub struct FilterTelemetry {
    /// Shard count (power of two).
    pub shards: usize,
    /// Live entries across all shards.
    pub entries: usize,
    /// Total slot capacity across all shards (active generations).
    pub capacity_slots: usize,
    /// Aggregate load factor (`entries / capacity_slots`).
    pub load_factor: f64,
    /// Per-shard load factors, in shard order — skew here means the
    /// key space is hashing unevenly.
    pub shard_load: Vec<f64>,
    /// Lookup probes answered (all shards, lifetime).
    pub lookups: u64,
    /// Bucket slots examined across all lookups — divide by `lookups`
    /// for the mean probe count temperature sorting optimizes.
    pub slots_probed: u64,
    /// Cuckoo displacements performed by inserts.
    pub kicks: u64,
    /// Placements by displacement-chain depth; bucket ranges are
    /// documented at [`KICK_DEPTH_BUCKETS`].
    pub kick_depth_hist: [u64; KICK_DEPTH_BUCKETS],
    /// Table doublings triggered.
    pub expansions: u64,
    /// Incremental migration steps driven (several per expansion).
    pub migration_steps: u64,
    /// Approximate heap bytes, including freed block-list capacity.
    pub memory_bytes: usize,
    /// Heap bytes backing live entries only.
    pub live_memory_bytes: usize,
    /// Analytic false-positive probability at the current load
    /// (capacity-weighted across shards).
    pub est_fp_rate: f64,
}

impl FilterTelemetry {
    /// JSON form for the `\x01stats` payload (`"filter"` sub-object).
    /// These are *additive* fields — new keys here never collide with
    /// the historical top-level stats names the router's prober reads.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("entries", Json::Num(self.entries as f64)),
            ("capacity_slots", Json::Num(self.capacity_slots as f64)),
            ("load_factor", Json::Num(self.load_factor)),
            (
                "shard_load",
                Json::Arr(self.shard_load.iter().map(|&l| Json::Num(l)).collect()),
            ),
            ("lookups", Json::Num(self.lookups as f64)),
            ("slots_probed", Json::Num(self.slots_probed as f64)),
            ("kicks", Json::Num(self.kicks as f64)),
            (
                "kick_depth_hist",
                Json::Arr(
                    self.kick_depth_hist
                        .iter()
                        .map(|&c| Json::Num(c as f64))
                        .collect(),
                ),
            ),
            ("expansions", Json::Num(self.expansions as f64)),
            ("migration_steps", Json::Num(self.migration_steps as f64)),
            ("memory_bytes", Json::Num(self.memory_bytes as f64)),
            ("live_memory_bytes", Json::Num(self.live_memory_bytes as f64)),
            ("est_fp_rate", Json::Num(self.est_fp_rate)),
        ])
    }
}

/// A Cuckoo Filter partitioned across independent, individually locked
/// shards. All operations take `&self`; see the module docs for which
/// take read vs write locks.
#[derive(Debug)]
pub struct ShardedCuckooFilter {
    shards: Vec<RwLock<CuckooFilter>>,
}

impl ShardedCuckooFilter {
    /// Build with `nshards` shards (rounded up to a power of two). The
    /// configured `initial_buckets` is the *total* across shards, split
    /// with ceiling division so the sharded filter never starts with
    /// less capacity than configured (floor division used to shrink
    /// e.g. 10 buckets over 4 shards to 8 and force earlier expansions).
    pub fn new(cfg: CuckooConfig, nshards: usize) -> Self {
        let n = nshards.max(1).next_power_of_two();
        let shards = (0..n)
            .map(|i| {
                RwLock::new(CuckooFilter::new(CuckooConfig {
                    initial_buckets: cfg.initial_buckets.div_ceil(n).max(1),
                    // decorrelate eviction choices across shards
                    seed: cfg.seed ^ (0x9E37_79B9_7F4A_7C15u64
                        .wrapping_mul(i as u64 + 1)),
                    ..cfg
                }))
            })
            .collect();
        ShardedCuckooFilter { shards }
    }

    /// Number of shards (power of two).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<CuckooFilter> {
        &self.shards[shard_index(key, self.shards.len())]
    }

    /// Insert an entity with its addresses (shard write lock; bounded —
    /// one placement plus at most one migration step). Duplicate keys
    /// are rejected, matching [`CuckooFilter::insert`].
    pub fn insert(&self, key: u64, addrs: &[EntityAddress]) -> bool {
        self.shard(key).write().unwrap().insert(key, addrs)
    }

    /// Remove an entity (shard write lock); reclaims its block list.
    pub fn delete(&self, key: u64) -> bool {
        self.shard(key).write().unwrap().delete(key)
    }

    /// Append an address to an existing entity (shard write lock).
    pub fn push_address(&self, key: u64, addr: EntityAddress) -> bool {
        self.shard(key).write().unwrap().push_address(key, addr)
    }

    /// Fingerprint membership probe (shard read lock).
    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains(key)
    }

    /// Exact membership (shard read lock).
    pub fn contains_exact(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains_exact(key)
    }

    /// Lookup: append all addresses of `key` to `out` and return whether
    /// the entity was found. Takes only the shard **read** lock — the
    /// concurrent serving hot path — even while the shard is mid-
    /// expansion (both table generations are probed under the same
    /// guard). Addresses are copied out under the guard, so the returned
    /// data is consistent even if a writer reshapes the shard
    /// immediately after. If a migration is pending, one bounded step is
    /// driven opportunistically through `try_write` after the guard
    /// drops — never blocking this or any other reader.
    pub fn lookup_into(&self, key: u64, out: &mut Vec<EntityAddress>) -> bool {
        let lock = self.shard(key);
        let (found, migrating) = {
            let shard = lock.read().unwrap();
            let found = match shard.lookup_shared(key) {
                Some(hit) => {
                    out.extend(shard.addresses_iter(hit));
                    true
                }
                None => false,
            };
            (found, shard.migration_pending())
        };
        if migrating {
            // Non-blocking help: a failed try_write means another thread
            // holds the lock and is therefore already making progress.
            if let Ok(mut shard) = lock.try_write() {
                shard.migrate_step();
            }
        }
        found
    }

    /// Lookup returning a fresh `Vec` (`None` on miss). Read lock only.
    pub fn lookup_collect(&self, key: u64) -> Option<Vec<EntityAddress>> {
        let mut out = Vec::new();
        self.lookup_into(key, &mut out).then_some(out)
    }

    /// Temperature of a key, if present (shard read lock; test/bench).
    pub fn temperature(&self, key: u64) -> Option<u32> {
        self.shard(key).read().unwrap().temperature(key)
    }

    /// Export every live entry across all shards as `(key, temperature,
    /// addresses)` — the snapshot image. Takes each shard's read lock in
    /// turn, so the export is per-shard consistent (the snapshot's
    /// global cut point is the op-log position, not this scan).
    pub fn export_entries(&self) -> Vec<(u64, u32, Vec<EntityAddress>)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.read().unwrap().export_entries());
        }
        out
    }

    /// Drop every entry in every shard (restore path: a loaded snapshot
    /// is authoritative, so the forest-built index is cleared first).
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
    }

    /// Re-place one snapshot entry in its shard (write lock); replaces
    /// any existing entry for the key. See
    /// [`CuckooFilter::restore_entry`].
    pub fn restore_entry(
        &self,
        key: u64,
        temp: u32,
        addrs: &[EntityAddress],
    ) -> bool {
        self.shard(key).write().unwrap().restore_entry(key, temp, addrs)
    }

    /// Position of the key's slot within its bucket (test/bench helper;
    /// shard read lock).
    pub fn bucket_position(&self, key: u64) -> Option<usize> {
        self.shard(key).read().unwrap().bucket_position(key)
    }

    /// True while any shard has a doubling migration in flight
    /// (bench/test observability).
    pub fn any_migration_pending(&self) -> bool {
        self.shards
            .iter()
            .any(|s| s.read().unwrap().migration_pending())
    }

    /// Maintenance, epoch-style: per shard, first drain any pending
    /// expansion migration one bounded step per write-lock acquisition,
    /// then re-sort dirty buckets by temperature — planned under the
    /// shard *read* lock, swapped in validated-bucket-by-bucket under
    /// short write locks ([`MAINTAIN_SWAP_BATCH`] buckets per hold).
    /// Readers of the same shard interleave with every step, and
    /// readers of other shards are never touched at all.
    pub fn maintain(&self) {
        for lock in &self.shards {
            // one read-locked check for the common no-migration case;
            // the write-locked step loop releases the lock between
            // steps (the guard is a temporary of the loop condition)
            // and terminates via migrate_step's own pending signal
            if lock.read().unwrap().migration_pending() {
                while lock.write().unwrap().migrate_step() {}
            }
            let plans = lock.read().unwrap().plan_maintenance();
            for chunk in plans.chunks(MAINTAIN_SWAP_BATCH) {
                let mut shard = lock.write().unwrap();
                for plan in chunk {
                    shard.apply_bucket_plan(plan);
                }
            }
        }
    }

    /// Entries stored across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// True if no shard holds entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total capacity in slots across all shards (each shard reports its
    /// active generation — the doubled target while migrating).
    pub fn capacity_slots(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().capacity_slots())
            .sum()
    }

    /// Aggregate load factor: total entries / total slots.
    pub fn load_factor(&self) -> f64 {
        let (len, slots) = self.shards.iter().fold((0usize, 0usize), |acc, s| {
            let g = s.read().unwrap();
            (acc.0 + g.len(), acc.1 + g.capacity_slots())
        });
        if slots == 0 {
            0.0
        } else {
            len as f64 / slots as f64
        }
    }

    /// Counters summed across shards.
    pub fn stats(&self) -> CuckooStats {
        let mut total = CuckooStats::default();
        for shard in &self.shards {
            total.merge(shard.read().unwrap().stats());
        }
        total
    }

    /// Approximate heap bytes across all shards.
    pub fn memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().memory_bytes())
            .sum()
    }

    /// Heap bytes backing **live** entries across all shards (freed
    /// block-list capacity excluded) — what a rebalance drop pass
    /// actually reclaims. See [`CuckooFilter::live_memory_bytes`].
    pub fn live_memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().live_memory_bytes())
            .sum()
    }

    /// Per-shard load factors in shard order (monitoring; one read
    /// lock per shard, no cross-shard atomicity promise).
    pub fn shard_occupancy(&self) -> Vec<f64> {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().load_factor())
            .collect()
    }

    /// `(lookups, slots_probed)` summed across shards — the pair the
    /// tracer diffs around a retrieval stage to attribute probe work
    /// to one request.
    pub fn probe_counters(&self) -> (u64, u64) {
        let mut lookups = 0u64;
        let mut probed = 0u64;
        for shard in &self.shards {
            let s = shard.read().unwrap().stats();
            lookups += s.lookups;
            probed += s.slots_probed;
        }
        (lookups, probed)
    }

    /// Assemble a full [`FilterTelemetry`] snapshot. Locks each shard
    /// once (read), so the numbers within one shard are consistent;
    /// across shards they are monitoring-grade, like every other
    /// aggregate accessor here.
    pub fn telemetry(&self) -> FilterTelemetry {
        let mut stats = CuckooStats::default();
        let mut entries = 0usize;
        let mut slots = 0usize;
        let mut memory = 0usize;
        let mut live = 0usize;
        let mut shard_load = Vec::with_capacity(self.shards.len());
        // capacity-weighted false-positive estimate: each shard probes
        // only its own table, so the fleet-level rate is the average
        // weighted by how much of the key space (∝ slots) each serves
        let mut fp_weighted = 0.0f64;
        for lock in &self.shards {
            let g = lock.read().unwrap();
            stats.merge(g.stats());
            entries += g.len();
            let cap = g.capacity_slots();
            slots += cap;
            memory += g.memory_bytes();
            live += g.live_memory_bytes();
            shard_load.push(g.load_factor());
            fp_weighted += g.estimated_fp_rate() * cap as f64;
        }
        FilterTelemetry {
            shards: self.shards.len(),
            entries,
            capacity_slots: slots,
            load_factor: if slots == 0 { 0.0 } else { entries as f64 / slots as f64 },
            shard_load,
            lookups: stats.lookups,
            slots_probed: stats.slots_probed,
            kicks: stats.kicks,
            kick_depth_hist: stats.kick_depth_hist,
            expansions: stats.expansions,
            migration_steps: stats.migration_steps,
            memory_bytes: memory,
            live_memory_bytes: live,
            est_fp_rate: if slots == 0 { 0.0 } else { fp_weighted / slots as f64 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::fingerprint::entity_key;

    fn key(i: u64) -> u64 {
        entity_key(&format!("sharded-{i}"))
    }

    fn addrs(n: u32) -> Vec<EntityAddress> {
        (0..n).map(|i| EntityAddress::new(i, i)).collect()
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 3);
        assert_eq!(cf.num_shards(), 4);
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 0);
        assert_eq!(cf.num_shards(), 1);
    }

    #[test]
    fn capacity_never_below_configured() {
        // Regression for the floor-division sizing bug: 10 buckets over
        // 4 shards used to yield 2 buckets/shard = 32 slots, below the
        // configured 40. Ceiling division (then per-shard power-of-two
        // rounding) must always reach at least the configured capacity.
        for (buckets, shards) in [(10usize, 4usize), (1, 8), (1000, 16), (7, 2)]
        {
            let cfg =
                CuckooConfig { initial_buckets: buckets, ..CuckooConfig::default() };
            let cf = ShardedCuckooFilter::new(cfg, shards);
            assert!(
                cf.capacity_slots() >= buckets * cfg.slots,
                "{buckets} buckets over {shards} shards: {} slots < {}",
                cf.capacity_slots(),
                buckets * cfg.slots
            );
        }
    }

    #[test]
    // thousands of keyed ops: too slow under Miri (the small tests
    // cover the same paths)
    #[cfg_attr(miri, ignore)]
    fn insert_lookup_delete_roundtrip() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 8);
        for i in 0..2000 {
            assert!(cf.insert(key(i), &addrs(2)), "insert {i}");
        }
        assert_eq!(cf.len(), 2000);
        for i in 0..2000 {
            assert_eq!(cf.lookup_collect(key(i)).as_deref(), Some(&addrs(2)[..]));
        }
        for i in 0..2000 {
            assert!(cf.delete(key(i)), "delete {i}");
        }
        assert!(cf.is_empty());
    }

    #[test]
    fn duplicate_and_missing_semantics_match_unsharded() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        assert!(cf.insert(key(1), &addrs(1)));
        assert!(!cf.insert(key(1), &addrs(3)), "duplicate rejected");
        assert!(!cf.delete(key(2)));
        assert!(!cf.push_address(key(2), EntityAddress::new(0, 0)));
        assert!(cf.push_address(key(1), EntityAddress::new(7, 7)));
        assert_eq!(cf.lookup_collect(key(1)).unwrap().len(), 2);
        assert!(cf.lookup_collect(key(2)).is_none());
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn agrees_with_unsharded_filter() {
        let mut plain = CuckooFilter::new(CuckooConfig::default());
        let sharded = ShardedCuckooFilter::new(CuckooConfig::default(), 8);
        for i in 0..3000 {
            let a = addrs((i % 5) as u32);
            assert_eq!(plain.insert(key(i), &a), sharded.insert(key(i), &a));
        }
        // Neither design may produce a false negative; address lists may
        // differ only at the paper's near-zero fingerprint-shadowing
        // rate (§4.5.1), which is layout- and therefore design-dependent.
        let mut mismatches = 0usize;
        for i in 0..3000 {
            let want = plain.lookup(key(i)).map(|h| plain.addresses(h));
            let got = sharded.lookup_collect(key(i));
            assert!(want.is_some(), "plain false negative for {i}");
            assert!(got.is_some(), "sharded false negative for {i}");
            if got != want {
                mismatches += 1;
            }
        }
        assert!(mismatches <= 10, "shadow rate too high: {mismatches}/3000");
    }

    #[test]
    fn temperature_bumps_through_read_path() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        cf.insert(key(1), &addrs(1));
        let mut out = Vec::new();
        for _ in 0..5 {
            out.clear();
            assert!(cf.lookup_into(key(1), &mut out));
        }
        assert_eq!(cf.temperature(key(1)), Some(5));
        cf.maintain(); // must not deadlock or lose the entry
        assert!(cf.contains_exact(key(1)));
    }

    #[test]
    fn epoch_maintain_sorts_hot_entities_front() {
        // Single shard, single bucket: the epoch-style plan/swap pass
        // must produce the same ordering the monolithic sort did.
        let cf = ShardedCuckooFilter::new(
            CuckooConfig {
                initial_buckets: 1,
                slots: 4,
                load_threshold: 1.0,
                ..CuckooConfig::default()
            },
            1,
        );
        let (a, b, c) = (key(10), key(20), key(30));
        cf.insert(a, &addrs(1));
        cf.insert(b, &addrs(1));
        cf.insert(c, &addrs(1));
        let mut out = Vec::new();
        for _ in 0..10 {
            out.clear();
            cf.lookup_into(c, &mut out);
        }
        cf.maintain();
        assert_eq!(cf.bucket_position(c), Some(0), "hottest first");
        assert!(cf.contains_exact(a) && cf.contains_exact(b));
    }

    #[test]
    fn stats_aggregate_across_shards() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        for i in 0..100 {
            cf.insert(key(i), &addrs(1));
        }
        let mut out = Vec::new();
        for i in 0..100 {
            out.clear();
            cf.lookup_into(key(i), &mut out);
        }
        let s = cf.stats();
        assert_eq!(s.inserts, 100);
        assert_eq!(s.lookups, 100);
        assert!(s.slots_probed >= 100);
        assert!(cf.memory_bytes() > 0);
    }

    #[test]
    fn telemetry_snapshot_is_consistent_and_serializes() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        for i in 0..200 {
            cf.insert(key(i), &addrs(1));
        }
        let mut out = Vec::new();
        for i in 0..200 {
            out.clear();
            cf.lookup_into(key(i), &mut out);
        }
        let t = cf.telemetry();
        assert_eq!(t.shards, 4);
        assert_eq!(t.entries, 200);
        assert_eq!(t.capacity_slots, cf.capacity_slots());
        assert!((t.load_factor - cf.load_factor()).abs() < 1e-12);
        assert_eq!(t.shard_load.len(), 4);
        assert!(t.shard_load.iter().all(|&l| (0.0..=1.0).contains(&l)));
        assert_eq!(t.lookups, 200);
        assert!(t.slots_probed >= 200);
        assert!(t.kick_depth_hist.iter().sum::<u64>() >= 200, "every placement bucketed");
        assert!(t.memory_bytes >= t.live_memory_bytes);
        assert!(t.est_fp_rate > 0.0 && t.est_fp_rate < 1.0);
        assert_eq!(cf.probe_counters(), (t.lookups, t.slots_probed));

        let json = Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(json.get("shards").and_then(Json::as_f64), Some(4.0));
        assert_eq!(json.get("entries").and_then(Json::as_f64), Some(200.0));
        let hist = json.get("kick_depth_hist").unwrap();
        match hist {
            Json::Arr(items) => assert_eq!(items.len(), KICK_DEPTH_BUCKETS),
            other => panic!("kick_depth_hist not an array: {other:?}"),
        }
        assert!(json.get("est_fp_rate").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn expansion_inside_a_shard_preserves_entries() {
        // total capacity 8 buckets over 4 shards -> 2 buckets/shard;
        // thousands of inserts force many per-shard expansions.
        let cf = ShardedCuckooFilter::new(
            CuckooConfig { initial_buckets: 8, ..CuckooConfig::default() },
            4,
        );
        for i in 0..5000 {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
        }
        assert!(cf.stats().expansions >= 4, "each shard should have grown");
        for i in 0..5000 {
            assert!(cf.lookup_collect(key(i)).is_some(), "lost {i}");
        }
    }

    #[test]
    fn lookups_exact_while_migration_pending() {
        // Tiny steps + no maintain: inserts leave a migration visibly in
        // flight, and every key must stay exactly addressable through
        // the read path while the shard serves from both generations.
        let cf = ShardedCuckooFilter::new(
            CuckooConfig {
                initial_buckets: 64,
                migration_step_buckets: 1,
                ..CuckooConfig::default()
            },
            1,
        );
        let n = 300u64;
        for i in 0..n {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
        }
        assert!(cf.any_migration_pending(), "migration should be in flight");
        for i in 0..n {
            assert_eq!(
                cf.lookup_collect(key(i)).as_deref(),
                Some(&addrs(1)[..]),
                "key {i} mid-migration"
            );
        }
        // lookups opportunistically drove steps; drain the rest
        cf.maintain();
        assert!(!cf.any_migration_pending());
        assert_eq!(cf.len(), n as usize);
    }

    #[test]
    fn export_clear_restore_roundtrips_across_shards() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        for i in 0..250u64 {
            assert!(cf.insert(key(i), &addrs((i % 3 + 1) as u32)));
        }
        let mut exported = cf.export_entries();
        assert_eq!(exported.len(), 250);
        cf.clear();
        assert!(cf.is_empty());
        for (k, t, a) in &exported {
            assert!(cf.restore_entry(*k, *t, a));
        }
        assert_eq!(cf.len(), 250);
        let mut back = cf.export_entries();
        exported.sort();
        back.sort();
        assert_eq!(exported, back);
        assert_eq!(
            cf.lookup_collect(key(5)).as_deref(),
            Some(&addrs(3)[..])
        );
    }
}
