//! The cornerstone equivalence test: all four retrieval algorithms must
//! return the *same address set* for every entity of randomly generated
//! forests — the Cuckoo/Bloom structures only accelerate, never change,
//! retrieval semantics. (Paper §4: accuracy invariance across methods.)

use std::sync::Arc;

use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::data::orgchart::{OrgChartConfig, OrgChartDataset};
use cft_rag::rag::config::{Algorithm, RagConfig};
use cft_rag::rag::pipeline::make_retriever;
use cft_rag::util::proptest::forall_simple;

fn assert_all_agree(forest: Arc<cft_rag::forest::Forest>) {
    let mut retrievers: Vec<_> = Algorithm::ALL
        .iter()
        .map(|&algorithm| {
            make_retriever(
                forest.clone(),
                &RagConfig { algorithm, ..RagConfig::default() },
            )
        })
        .collect();

    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    for name in &names {
        let id = forest.entity_id(name).unwrap();
        let mut want = forest.scan_addresses(id);
        want.sort();
        for r in retrievers.iter_mut() {
            let mut got = r.find(name);
            got.sort();
            assert_eq!(
                got,
                want,
                "{} disagrees with scan for entity '{name}'",
                r.name()
            );
        }
    }
    // unknown entities: everyone returns empty
    for r in retrievers.iter_mut() {
        assert!(r.find("definitely-not-an-entity").is_empty());
    }
}

#[test]
fn agree_on_hospital_forests() {
    for trees in [1usize, 5, 25] {
        let forest = Arc::new(
            HospitalDataset::generate(HospitalConfig {
                trees,
                ..HospitalConfig::default()
            })
            .build_forest(),
        );
        assert_all_agree(forest);
    }
}

#[test]
fn agree_on_orgchart_forests() {
    let forest = Arc::new(
        OrgChartDataset::generate(OrgChartConfig {
            trees: 15,
            ..OrgChartConfig::default()
        })
        .build_forest(),
    );
    assert_all_agree(forest);
}

#[test]
fn agree_on_random_seeds() {
    forall_simple(
        8,
        |rng| rng.next_u64(),
        |&seed| {
            let forest = Arc::new(
                HospitalDataset::generate(HospitalConfig {
                    trees: 8,
                    seed,
                    ..HospitalConfig::default()
                })
                .build_forest(),
            );
            // spot-check a sample of entities for speed
            let mut retrievers: Vec<_> = Algorithm::ALL
                .iter()
                .map(|&algorithm| {
                    make_retriever(
                        forest.clone(),
                        &RagConfig { algorithm, ..RagConfig::default() },
                    )
                })
                .collect();
            let names: Vec<String> = forest
                .interner()
                .iter()
                .map(|(_, n)| n.to_string())
                .take(40)
                .collect();
            for name in &names {
                let id = forest.entity_id(name).unwrap();
                let mut want = forest.scan_addresses(id);
                want.sort();
                for r in retrievers.iter_mut() {
                    let mut got = r.find(name);
                    got.sort();
                    if got != want {
                        return Err(format!(
                            "{} disagrees on '{name}' (seed {seed})",
                            r.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn repeated_queries_and_maintenance_do_not_change_results() {
    let forest = Arc::new(
        HospitalDataset::generate(HospitalConfig {
            trees: 10,
            ..HospitalConfig::default()
        })
        .build_forest(),
    );
    let mut cf = make_retriever(
        forest.clone(),
        &RagConfig { algorithm: Algorithm::Cuckoo, ..RagConfig::default() },
    );
    let id = forest.entity_id("cardiology").unwrap();
    let mut want = forest.scan_addresses(id);
    want.sort();
    for round in 0..20 {
        let mut got = cf.find("cardiology");
        got.sort();
        assert_eq!(got, want, "round {round}");
        cf.maintain();
    }
}
