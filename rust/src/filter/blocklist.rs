//! Block linked lists of entity addresses (paper §3.1).
//!
//! Every Cuckoo Filter entry points at the head of a *block linked list*
//! holding all addresses of that entity across the forest. Blocks pack
//! several addresses per node, so — versus a classic linked list — the
//! list has far fewer nodes, far less pointer overhead, near-sequential
//! iteration, and O(1) append at the head block. All blocks live in one
//! shared arena (`Vec<Block>`), which removes per-list allocations and
//! the memory fragmentation the paper calls out.
//!
//! Deleted lists are returned to an intrusive **free list** (threaded
//! through the `next` field of dead blocks), so insert/delete churn
//! reuses slots instead of growing the arena without bound.

use crate::forest::EntityAddress;

/// Sentinel for "no block".
pub const NIL: u32 = u32::MAX;

/// Addresses per block. 14 × 8 B of payload + len/next keeps a block at
/// 120 B ≈ two cache lines.
pub const BLOCK_CAP: usize = 14;

#[derive(Clone, Debug)]
struct Block {
    addrs: [EntityAddress; BLOCK_CAP],
    len: u8,
    next: u32,
}

impl Block {
    fn empty(next: u32) -> Block {
        Block {
            addrs: [EntityAddress::new(0, 0); BLOCK_CAP],
            len: 0,
            next,
        }
    }
}

/// Arena of blocks shared by every list in one Cuckoo Filter.
#[derive(Clone, Debug)]
pub struct BlockArena {
    blocks: Vec<Block>,
    /// Head of the intrusive free list (`NIL` when empty).
    free_head: u32,
    /// Blocks currently on the free list.
    free_len: usize,
}

impl Default for BlockArena {
    fn default() -> Self {
        BlockArena { blocks: Vec::new(), free_head: NIL, free_len: 0 }
    }
}

impl BlockArena {
    /// New empty arena.
    pub fn new() -> Self {
        Self::default()
    }

    /// Place a block, reusing a freed slot when one is available.
    fn alloc(&mut self, b: Block) -> u32 {
        if self.free_head != NIL {
            let idx = self.free_head;
            self.free_head = self.blocks[idx as usize].next;
            self.free_len -= 1;
            self.blocks[idx as usize] = b;
            idx
        } else {
            self.blocks.push(b);
            (self.blocks.len() - 1) as u32
        }
    }

    /// Build a list from a slice of addresses; returns the head index
    /// (`NIL` for an empty slice).
    pub fn build(&mut self, addrs: &[EntityAddress]) -> u32 {
        let mut head = NIL;
        for chunk in addrs.rchunks(BLOCK_CAP) {
            let mut b = Block::empty(head);
            b.addrs[..chunk.len()].copy_from_slice(chunk);
            b.len = chunk.len() as u8;
            head = self.alloc(b);
        }
        head
    }

    /// Append one address, returning the (possibly new) head index.
    /// O(1): fills the head block or prepends a fresh one.
    pub fn push(&mut self, head: u32, addr: EntityAddress) -> u32 {
        if head != NIL {
            let b = &mut self.blocks[head as usize];
            if (b.len as usize) < BLOCK_CAP {
                b.addrs[b.len as usize] = addr;
                b.len += 1;
                return head;
            }
        }
        let mut b = Block::empty(head);
        b.addrs[0] = addr;
        b.len = 1;
        self.alloc(b)
    }

    /// Return a whole list's blocks to the free list (delete path).
    /// `NIL` is a no-op. Returns how many blocks were reclaimed. The
    /// caller must not use `head` afterwards.
    pub fn free_chain(&mut self, head: u32) -> usize {
        let mut n = 0;
        let mut cur = head;
        while cur != NIL {
            let next = self.blocks[cur as usize].next;
            self.blocks[cur as usize].len = 0;
            self.blocks[cur as usize].next = self.free_head;
            self.free_head = cur;
            self.free_len += 1;
            n += 1;
            cur = next;
        }
        n
    }

    /// Iterate all addresses of a list.
    pub fn iter(&self, head: u32) -> BlockIter<'_> {
        BlockIter { arena: self, block: head, pos: 0 }
    }

    /// Number of addresses in a list (walks the chain).
    pub fn count(&self, head: u32) -> usize {
        let mut n = 0;
        let mut cur = head;
        while cur != NIL {
            let b = &self.blocks[cur as usize];
            n += b.len as usize;
            cur = b.next;
        }
        n
    }

    /// Total blocks ever allocated — the arena's high-water mark. Stays
    /// bounded under insert/delete churn because freed blocks are reused.
    pub fn blocks_allocated(&self) -> usize {
        self.blocks.len()
    }

    /// Blocks currently on the free list.
    pub fn blocks_free(&self) -> usize {
        self.free_len
    }

    /// Blocks currently backing live lists.
    pub fn blocks_in_use(&self) -> usize {
        self.blocks.len() - self.free_len
    }

    /// Approximate heap bytes used by the arena.
    pub fn memory_bytes(&self) -> usize {
        self.blocks.capacity() * std::mem::size_of::<Block>()
    }

    /// Bytes backing **live** lists only: freed blocks (delete churn,
    /// the rebalancer's disowned-key drop pass) stop counting here even
    /// though the arena keeps their capacity for reuse — the measure of
    /// how much index a backend actually still holds.
    pub fn live_bytes(&self) -> usize {
        self.blocks_in_use() * std::mem::size_of::<Block>()
    }
}

/// Iterator over one block list.
pub struct BlockIter<'a> {
    arena: &'a BlockArena,
    block: u32,
    pos: usize,
}

impl<'a> Iterator for BlockIter<'a> {
    type Item = EntityAddress;

    fn next(&mut self) -> Option<EntityAddress> {
        while self.block != NIL {
            let b = &self.arena.blocks[self.block as usize];
            if self.pos < b.len as usize {
                let a = b.addrs[self.pos];
                self.pos += 1;
                return Some(a);
            }
            self.block = b.next;
            self.pos = 0;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(i: u32) -> EntityAddress {
        EntityAddress::new(i / 100, i % 100)
    }

    #[test]
    fn build_and_iterate_roundtrip() {
        let mut arena = BlockArena::new();
        let addrs: Vec<EntityAddress> = (0..40).map(addr).collect();
        let head = arena.build(&addrs);
        let got: Vec<EntityAddress> = arena.iter(head).collect();
        assert_eq!(got, addrs);
        assert_eq!(arena.count(head), 40);
    }

    #[test]
    fn empty_list() {
        let mut arena = BlockArena::new();
        let head = arena.build(&[]);
        assert_eq!(head, NIL);
        assert_eq!(arena.count(head), 0);
        assert_eq!(arena.iter(head).count(), 0);
    }

    #[test]
    fn push_fills_head_then_prepends() {
        let mut arena = BlockArena::new();
        let mut head = arena.build(&[addr(0)]);
        for i in 1..BLOCK_CAP as u32 {
            let nh = arena.push(head, addr(i));
            assert_eq!(nh, head, "fills in place until the block is full");
            head = nh;
        }
        assert_eq!(arena.blocks_allocated(), 1);
        head = arena.push(head, addr(99));
        assert_eq!(arena.blocks_allocated(), 2, "new head block");
        assert_eq!(arena.count(head), BLOCK_CAP + 1);
        let got: Vec<EntityAddress> = arena.iter(head).collect();
        assert!(got.contains(&addr(99)));
    }

    #[test]
    fn push_to_nil_starts_list() {
        let mut arena = BlockArena::new();
        let head = arena.push(NIL, addr(7));
        assert_ne!(head, NIL);
        assert_eq!(arena.iter(head).collect::<Vec<_>>(), vec![addr(7)]);
    }

    #[test]
    fn block_packing_density() {
        let mut arena = BlockArena::new();
        let addrs: Vec<EntityAddress> = (0..1000).map(addr).collect();
        arena.build(&addrs);
        let blocks = arena.blocks_allocated();
        // ceil(1000 / 14) = 72
        assert_eq!(blocks, 1000usize.div_ceil(BLOCK_CAP));
    }

    #[test]
    fn free_chain_reclaims_and_alloc_reuses() {
        let mut arena = BlockArena::new();
        let addrs: Vec<EntityAddress> = (0..3 * BLOCK_CAP as u32).map(addr).collect();
        let head = arena.build(&addrs);
        assert_eq!(arena.blocks_allocated(), 3);
        assert_eq!(arena.blocks_in_use(), 3);
        assert_eq!(arena.free_chain(head), 3);
        assert_eq!(arena.blocks_free(), 3);
        assert_eq!(arena.blocks_in_use(), 0);
        // rebuilding reuses the freed slots: no arena growth
        let head2 = arena.build(&addrs);
        assert_eq!(arena.blocks_allocated(), 3, "slots reused, not grown");
        assert_eq!(arena.blocks_free(), 0);
        let got: Vec<EntityAddress> = arena.iter(head2).collect();
        assert_eq!(got, addrs);
    }

    #[test]
    fn free_nil_is_noop() {
        let mut arena = BlockArena::new();
        assert_eq!(arena.free_chain(NIL), 0);
        assert_eq!(arena.blocks_free(), 0);
    }

    #[test]
    // 28k arena ops: too slow under Miri
    #[cfg_attr(miri, ignore)]
    fn churn_bounded_by_live_set() {
        let mut arena = BlockArena::new();
        for round in 0..1000u32 {
            let addrs: Vec<EntityAddress> =
                (0..2 * BLOCK_CAP as u32).map(|i| addr(round + i)).collect();
            let head = arena.build(&addrs);
            arena.free_chain(head);
        }
        assert_eq!(arena.blocks_allocated(), 2, "churn must not grow the arena");
    }

    #[test]
    fn freeing_one_list_leaves_others_intact() {
        let mut arena = BlockArena::new();
        let h1 = arena.build(&(0..20).map(addr).collect::<Vec<_>>());
        let h2 = arena.build(&(100..120).map(addr).collect::<Vec<_>>());
        arena.free_chain(h1);
        let got: Vec<EntityAddress> = arena.iter(h2).collect();
        assert_eq!(got, (100..120).map(addr).collect::<Vec<_>>());
    }

    #[test]
    fn many_independent_lists_share_arena() {
        let mut arena = BlockArena::new();
        let h1 = arena.build(&[addr(1), addr(2)]);
        let h2 = arena.build(&[addr(3)]);
        assert_eq!(arena.iter(h1).count(), 2);
        assert_eq!(arena.iter(h2).count(), 1);
        assert_eq!(
            arena.iter(h2).next(),
            Some(addr(3)),
            "lists do not interfere"
        );
    }
}
