//! Serving metrics: request counters, stage latency histograms, batch
//! fill statistics — allocated out of the unified [`Registry`]
//! (`obs/registry.rs`), so every series here is also scrapeable
//! through the `\x01metrics` control line as Prometheus text. The
//! `\x01stats` JSON payload keeps its historical field names (the
//! shard router's health prober reads them); [`MetricsSnapshot`] is
//! that contract.

use std::time::Duration;

use crate::obs::{Counter, Histogram, Registry};
use crate::sync::Arc;
use crate::util::json::Json;

/// Snapshot of the counters at one instant.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub failures: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub total_mean_s: f64,
    pub total_p50_s: f64,
    pub total_p99_s: f64,
    pub retrieval_mean_s: f64,
    pub retrieval_p99_s: f64,
}

impl MetricsSnapshot {
    /// Requests per second given an elapsed window.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed.as_secs_f64()
        }
    }

    /// JSON form — the payload of the TCP protocol's `\x01stats`
    /// control line (`coordinator/tcp.rs`), which the shard router's
    /// health prober reads to see backend *load*, not just liveness.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch_fill", Json::Num(self.mean_batch_fill)),
            ("total_mean_s", Json::Num(self.total_mean_s)),
            ("total_p50_s", Json::Num(self.total_p50_s)),
            ("total_p99_s", Json::Num(self.total_p99_s)),
            ("retrieval_mean_s", Json::Num(self.retrieval_mean_s)),
            ("retrieval_p99_s", Json::Num(self.retrieval_p99_s)),
        ])
    }
}

/// Thread-shared metrics sink. Cloning shares the same underlying
/// series; recording is lock-free (relaxed atomics via `obs`).
#[derive(Clone, Debug)]
pub struct Metrics {
    registry: Arc<Registry>,
    requests: Arc<Counter>,
    failures: Arc<Counter>,
    batches: Arc<Counter>,
    batch_fill_sum: Arc<Counter>,
    total: Arc<Histogram>,
    retrieval: Arc<Histogram>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// New empty metrics (a fresh registry per coordinator).
    pub fn new() -> Self {
        let registry = Arc::new(Registry::new());
        let requests = registry
            .counter("cft_coordinator_requests_total", "requests completed successfully");
        let failures =
            registry.counter("cft_coordinator_failures_total", "requests that failed");
        let batches =
            registry.counter("cft_coordinator_batches_total", "embedding batches dispatched");
        let batch_fill_sum = registry.counter(
            "cft_coordinator_batch_fill_sum",
            "sum of batch fills (divide by batches for the mean)",
        );
        let total = registry.histogram(
            "cft_coordinator_request_seconds",
            "end-to-end request latency (submit to reply)",
        );
        let retrieval = registry.histogram(
            "cft_coordinator_retrieval_seconds",
            "filter-backed retrieval stage latency",
        );
        Metrics { registry, requests, failures, batches, batch_fill_sum, total, retrieval }
    }

    /// The registry backing this sink — the coordinator's `\x01metrics`
    /// exposition renders it (plus point-in-time gauges).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Record one completed request.
    pub fn record_request(&self, total: Duration, retrieval: Duration) {
        self.requests.inc();
        self.total.record_duration(total);
        self.retrieval.record_duration(retrieval);
    }

    /// Record one failed request.
    pub fn record_failure(&self) {
        self.failures.inc();
    }

    /// Record one dispatched batch of `fill` requests.
    pub fn record_batch(&self, fill: usize) {
        self.batches.inc();
        self.batch_fill_sum.add(fill as u64);
    }

    /// Current snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.get();
        MetricsSnapshot {
            requests: self.requests.get(),
            failures: self.failures.get(),
            batches,
            mean_batch_fill: if batches == 0 {
                0.0
            } else {
                self.batch_fill_sum.get() as f64 / batches as f64
            },
            total_mean_s: self.total.mean(),
            total_p50_s: self.total.quantile(0.5),
            total_p99_s: self.total.quantile(0.99),
            retrieval_mean_s: self.retrieval.mean(),
            retrieval_p99_s: self.retrieval.quantile(0.99),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(10), Duration::from_micros(50));
        m.record_request(Duration::from_millis(20), Duration::from_micros(70));
        m.record_batch(8);
        m.record_batch(4);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_fill - 6.0).abs() < 1e-12);
        assert!(s.total_mean_s > 0.009 && s.total_mean_s < 0.021);
        assert!(s.retrieval_mean_s > 0.0);
    }

    #[test]
    fn throughput_math() {
        let m = Metrics::new();
        for _ in 0..100 {
            m.record_request(Duration::from_millis(1), Duration::from_micros(1));
        }
        let s = m.snapshot();
        assert!((s.throughput(Duration::from_secs(10)) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(10), Duration::from_micros(50));
        m.record_failure();
        let json = m.snapshot().to_json();
        let back = Json::parse(&json.to_string()).unwrap();
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        assert_eq!(back.get("failures").and_then(Json::as_f64), Some(1.0));
        assert!(back.get("total_mean_s").and_then(Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.record_request(Duration::from_millis(1), Duration::from_micros(1));
        assert_eq!(m.snapshot().requests, 1);
    }

    #[test]
    fn registry_renders_every_series() {
        let m = Metrics::new();
        m.record_request(Duration::from_millis(2), Duration::from_micros(10));
        let text = m.registry().render();
        assert!(text.contains("# TYPE cft_coordinator_requests_total counter"));
        assert!(text.contains("# TYPE cft_coordinator_request_seconds histogram"));
        assert!(text.contains("cft_coordinator_request_seconds_bucket{le=\"+Inf\"} 1"));
    }
}
