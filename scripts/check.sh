#!/usr/bin/env bash
# Tier-1 verify + lint gate. Run from the repository root:
#
#   scripts/check.sh                      # fmt + clippy + build + test
#   scripts/check.sh --fast               # skip the release build
#   scripts/check.sh --obs                # observability smoke (shipped binary)
#   scripts/check.sh --crash              # SIGKILL crash-consistency harness
#   scripts/check.sh --analysis           # all deep-analysis jobs
#   scripts/check.sh --analysis modelcheck|miri|tsan   # one job
#
# CI runs exactly this script — the push/PR job runs the default gate,
# and the analysis jobs each run one `--analysis` selector — so the
# local gate and .github/workflows/ci.yml cannot drift. Keep in sync
# with ROADMAP.md ("Tier-1 verify") and docs/TESTING.md (the
# verification pyramid these jobs implement).
set -euo pipefail
cd "$(dirname "$0")/.."

# --------------------------------------------------------------------
# Deep analysis: deterministic model checking (stable toolchain) plus
# the two nightly sanitizer jobs. Nightly-only jobs degrade to a loud
# skip when the toolchain/component is missing, so `--analysis` is
# runnable on any dev box without lying about what it covered.
# --------------------------------------------------------------------
run_modelcheck() {
  # The default gate lints without the feature, so the shim/scheduler
  # code and the schedule suite are cfg'd out there — lint them here.
  echo "==> cargo clippy --features modelcheck -D warnings"
  cargo clippy --workspace --all-targets --features modelcheck -- -D warnings

  # The whole suite with the sync shims routed through the scheduler:
  # proves the feature changes nothing off-model, then explores the
  # schedule suite (tests/modelcheck_schedules.rs) seed by seed —
  # including the reply-cache fill-vs-invalidate schedules and the
  # checker_catches_unguarded_cache_fill companion.
  echo "==> cargo test --features modelcheck (schedule exploration)"
  cargo test -q --features modelcheck

  # The feature must be zero-overhead when disabled: the bench graph
  # (release profile, no feature) has to keep compiling against the
  # very same `sync` names the instrumented build wraps.
  echo "==> cargo bench --no-run (modelcheck off: zero-overhead check)"
  cargo bench --no-run
}

run_miri() {
  # Scoped to the filter unit tests: they drive the crate's one unsafe
  # read path (the SWAR bucket scan in filter/cuckoo.rs) through every
  # table geometry; heavyweight loops are #[cfg_attr(miri, ignore)]d.
  if ! cargo +nightly miri --version >/dev/null 2>&1; then
    echo "SKIP miri: nightly toolchain with the miri component not installed"
    echo "      (rustup toolchain install nightly && rustup +nightly component add miri)"
    return 0
  fi
  echo "==> cargo +nightly miri test --lib -- filter::"
  cargo +nightly miri test -p cft-rag --lib -- filter::
}

run_tsan() {
  # ThreadSanitizer over the real-thread suite: catches data races on
  # plain std primitives that the modelcheck shims do not wrap.
  # Needs nightly + rust-src (std is rebuilt instrumented).
  if ! cargo +nightly --version >/dev/null 2>&1; then
    echo "SKIP tsan: nightly toolchain not installed"
    return 0
  fi
  if ! rustup component list --toolchain nightly 2>/dev/null \
      | grep -q '^rust-src.*(installed)'; then
    echo "SKIP tsan: rust-src component missing on nightly"
    echo "      (rustup +nightly component add rust-src)"
    return 0
  fi
  local host
  host="$(rustc -vV | sed -n 's/^host: //p')"
  echo "==> ThreadSanitizer: cargo +nightly test (target $host)"
  RUSTFLAGS="-Z sanitizer=thread" \
    cargo +nightly test -p cft-rag -q -Z build-std --target "$host"
}

# --------------------------------------------------------------------
# Observability smoke: boot one traced coordinator binary and prove,
# over a real socket, that a sampled query reply carries its trace id,
# `\x01trace <id>` answers a span tree (with the retrieval stage and a
# coverage figure), and `\x01metrics` emits typed Prometheus text with
# +Inf-terminated histograms. The deep assertions live in
# rust/tests/observability.rs; this step proves the *shipped binary*
# wires them up end to end. Run alone: scripts/check.sh --obs
# --------------------------------------------------------------------
run_obs() {
  echo "==> obs smoke: traced serve + \\x01trace + \\x01metrics"
  cargo build --release --quiet
  local port="${OBS_SMOKE_PORT:-7917}"
  target/release/cft-rag serve --port "$port" --trees 12 --workers 2 \
    --trace-sample 1 &
  local srv=$!
  # shellcheck disable=SC2064  # expand $srv now: it is gone at trap time
  trap "kill $srv 2>/dev/null || true; wait $srv 2>/dev/null || true" RETURN

  local up=0
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/$port") 2>/dev/null; then
      up=1
      break
    fi
    sleep 0.1
  done
  [[ "$up" == 1 ]] || { echo "obs smoke: server never came up"; return 1; }

  exec 3<>"/dev/tcp/127.0.0.1/$port"
  printf 'what is the parent unit of cardiology\n' >&3
  local reply
  read -r reply <&3
  grep -q '"ok":true' <<<"$reply" \
    || { echo "obs smoke: query failed: $reply"; return 1; }
  local id
  id=$(sed -n 's/.*"trace":"\([0-9a-f]*\)".*/\1/p' <<<"$reply")
  [[ -n "$id" ]] \
    || { echo "obs smoke: sampled reply carries no trace id: $reply"; return 1; }

  printf '\x01trace %s\n' "$id" >&3
  local trace
  read -r trace <&3
  for want in '"stage":"retrieval"' '"coverage":' "\"id\":\"$id\""; do
    grep -qF "$want" <<<"$trace" \
      || { echo "obs smoke: $want missing from trace export: $trace"; return 1; }
  done

  printf '\x01metrics\n' >&3
  local metrics
  read -r metrics <&3
  for want in 'cft_coordinator_requests_total' '# TYPE' '+Inf' '_count'; do
    grep -qF "$want" <<<"$metrics" \
      || { echo "obs smoke: $want missing from metrics: $metrics"; return 1; }
  done

  printf ':quit\n' >&3
  exec 3<&- 3>&-
  echo "OK (obs smoke)"
}

# --------------------------------------------------------------------
# Crash consistency: SIGKILL real `cft-rag serve --data-dir` child
# processes mid-churn and prove the durable backend loses no acked
# write (tests/crash_consistency.rs; format proptests ride along in
# tests/prop_persist.rs). The harness prints each schedule's seed and a
# one-line replay command (CFT_CRASH_SEED=<seed> …) on failure — the
# modelcheck convention. Loud SKIP where subprocess supervision is
# unavailable (no /proc: sandboxed or exotic containers).
# --------------------------------------------------------------------
run_crash() {
  if [[ "$(uname -s)" != "Linux" && "$(uname -s)" != "Darwin" ]]; then
    echo "SKIP crash: needs a unix host (SIGKILL semantics)"
    return 0
  fi
  if [[ "$(uname -s)" == "Linux" && ! -d /proc ]]; then
    echo "SKIP crash: /proc unavailable — cannot supervise subprocesses"
    return 0
  fi
  if ! cargo --version >/dev/null 2>&1; then
    echo "SKIP crash: cargo not installed"
    return 0
  fi
  echo "==> cargo test --test crash_consistency (seeded SIGKILL schedules)"
  cargo test -q --test crash_consistency -- --nocapture
  echo "==> cargo test --test prop_persist (format roundtrip/corruption)"
  cargo test -q --test prop_persist
}

if [[ "${1:-}" == "--obs" ]]; then
  run_obs
  exit 0
fi

if [[ "${1:-}" == "--crash" ]]; then
  run_crash
  echo "OK (crash)"
  exit 0
fi

if [[ "${1:-}" == "--analysis" ]]; then
  case "${2:-all}" in
    modelcheck) run_modelcheck ;;
    miri)       run_miri ;;
    tsan)       run_tsan ;;
    all)        run_modelcheck; run_miri; run_tsan ;;
    *) echo "unknown analysis job '${2}' (modelcheck|miri|tsan)"; exit 2 ;;
  esac
  echo "OK (analysis)"
  exit 0
fi

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "==> cargo fmt --check"
cargo fmt --all --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

# Docs are a first-class deliverable (README.md + docs/PROTOCOL.md +
# docs/OPERATIONS.md + docs/TESTING.md + rustdoc): broken intra-doc
# links or malformed rustdoc fail the gate.
echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace --quiet

# Module docs carry runnable `# Examples` (router/{ring,pool,health,
# backend,metrics}.rs especially); run them explicitly so a drifted
# example fails the gate even if a harness config ever stops `cargo
# test` from picking doctests up implicitly.
echo "==> cargo test --doc"
cargo test --doc --quiet

if [[ "$fast" == 0 ]]; then
  echo "==> cargo build --release"
  cargo build --release

  # Examples are the documented entry points (serve_requests drives the
  # router mode); build them all so the multi-process serving path can't
  # silently rot out of the default build graph.
  echo "==> cargo build --release --examples"
  cargo build --release --examples

  # The harness=false benches are not part of the test build, so without
  # this they can bit-rot silently; --no-run compiles them without
  # running (benches/* are long-running and not pass/fail gates).
  echo "==> cargo bench --no-run"
  cargo bench --no-run
fi

# The full suite includes tests/router_integration.rs (real TCP
# backends in-process — the multi-process serving path) and the
# cache-consistency tier (tests/prop_cache.rs equivalence oracle plus
# the reply-cache integration test); cargo reports failing test names,
# so no separate named run is needed.
echo "==> cargo test -q"
cargo test -q

echo "OK"
