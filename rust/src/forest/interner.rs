//! Entity string interning: every distinct entity surface form gets a
//! dense `EntityId`, so trees, filters and workloads pass around `u32`s
//! instead of strings on the hot path.

use std::collections::HashMap;

/// Dense id of an interned entity name.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EntityId(pub u32);

/// Bidirectional entity-name table.
#[derive(Clone, Debug, Default)]
pub struct Interner {
    map: HashMap<String, EntityId>,
    names: Vec<String>,
}

impl Interner {
    /// New empty interner.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a name (normalized by the caller), returning its id.
    pub fn intern(&mut self, name: &str) -> EntityId {
        if let Some(&id) = self.map.get(name) {
            return id;
        }
        let id = EntityId(self.names.len() as u32);
        self.names.push(name.to_string());
        self.map.insert(name.to_string(), id);
        id
    }

    /// Lookup without inserting.
    pub fn get(&self, name: &str) -> Option<EntityId> {
        self.map.get(name).copied()
    }

    /// Name of an id. Panics on a foreign id.
    pub fn name(&self, id: EntityId) -> &str {
        &self.names[id.0 as usize]
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True if nothing interned.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Iterate over all (id, name) pairs.
    pub fn iter(&self) -> impl Iterator<Item = (EntityId, &str)> {
        self.names
            .iter()
            .enumerate()
            .map(|(i, n)| (EntityId(i as u32), n.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut i = Interner::new();
        let a = i.intern("cardiology");
        let b = i.intern("cardiology");
        assert_eq!(a, b);
        assert_eq!(i.len(), 1);
    }

    #[test]
    fn ids_are_dense() {
        let mut i = Interner::new();
        let a = i.intern("a");
        let b = i.intern("b");
        assert_eq!(a, EntityId(0));
        assert_eq!(b, EntityId(1));
    }

    #[test]
    fn roundtrip_name() {
        let mut i = Interner::new();
        let id = i.intern("surgery ward");
        assert_eq!(i.name(id), "surgery ward");
        assert_eq!(i.get("surgery ward"), Some(id));
        assert_eq!(i.get("missing"), None);
    }

    #[test]
    fn iter_covers_all() {
        let mut i = Interner::new();
        i.intern("x");
        i.intern("y");
        let all: Vec<_> = i.iter().map(|(_, n)| n.to_string()).collect();
        assert_eq!(all, vec!["x", "y"]);
    }
}
