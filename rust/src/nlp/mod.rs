//! NLP pre-processing substrate (paper §2): named-entity recognition,
//! hierarchical relationship extraction, and relationship filtering.

pub mod filter;
pub mod ner;
pub mod relate;
