//! Document corpus for the vector-search stage (Figure 1's first step).
//!
//! Wraps dataset documents in a store-ready form: id, title, body and the
//! padded token batch the embed artifact consumes.

use crate::text::tokenizer::tokenize_padded;

/// One retrievable document.
#[derive(Clone, Debug)]
pub struct Document {
    pub id: u32,
    pub title: String,
    pub body: String,
}

impl Document {
    /// Token ids for the embed artifact (`max_tokens` padded).
    pub fn tokens(&self, max_tokens: usize) -> Vec<i32> {
        let text = format!("{} {}", self.title, self.body);
        tokenize_padded(&text, max_tokens)
    }
}

/// Build documents from raw texts.
pub fn corpus_from_texts(texts: &[String]) -> Vec<Document> {
    texts
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let title = t.split('.').next().unwrap_or("").trim().to_string();
            Document { id: i as u32, title, body: t.clone() }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_documents_with_titles() {
        let texts = vec![
            "Mercy General Hospital was founded in 1910. It grew.".to_string(),
            "Riverside Clinic history. Ward nine opened.".to_string(),
        ];
        let docs = corpus_from_texts(&texts);
        assert_eq!(docs.len(), 2);
        assert_eq!(docs[0].title, "Mercy General Hospital was founded in 1910");
        assert_eq!(docs[1].id, 1);
    }

    #[test]
    fn tokens_padded() {
        let docs = corpus_from_texts(&["short doc.".to_string()]);
        let toks = docs[0].tokens(32);
        assert_eq!(toks.len(), 32);
    }
}
