//! Durable backend state: checksummed snapshot + append-only op log.
//!
//! The forest and its annotations are rebuildable from the corpus, so
//! they are **not** persisted. What a restart cannot rebuild is the
//! dynamic-update stream — every acknowledged `\x01insert` and
//! `\x01delete` since boot — and the membership epoch the backend was
//! serving. This module makes exactly that durable, dependency-free:
//!
//! * [`snapshot`] — a versioned, CRC-checksummed binary image of the
//!   filter's live entries (key, temperature, address list) plus the
//!   recorded `partition_epoch`, written atomically (temp file +
//!   rename + directory fsync).
//! * [`oplog`] — an append-only log of acked ops with per-record CRC
//!   and fsync-on-ack batching; a write is only acked after its record
//!   is durable (with `--fsync-every 1`).
//! * [`Store`] — the data-dir facade the coordinator talks to:
//!   `open()` recovers snapshot + log-replay on startup,
//!   [`Store::record`] appends-and-syncs on the ack path, and
//!   [`Store::write_snapshot`] cuts a new snapshot then truncates the
//!   (now redundant) log.
//!
//! On restart the recovered state lets the router's `EpochGate`
//! re-admit the backend at the *recorded* epoch and fetch only the
//! writes it missed while dead — O(delta) instead of the O(index)
//! network handoff a cold `\x01join` costs.
//!
//! Data-dir layout:
//!
//! ```text
//! <data-dir>/snapshot.cft       latest complete snapshot (or absent)
//! <data-dir>/snapshot.cft.tmp   atomic-write staging (transient)
//! <data-dir>/oplog.cft          ops acked since that snapshot
//! ```

pub mod crc;
pub mod oplog;
pub mod snapshot;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::forest::EntityAddress;
pub use oplog::{LogOp, OpLog, Replay, TailOutcome};
pub use snapshot::Snapshot;

/// Snapshot file name inside the data dir.
pub const SNAPSHOT_FILE: &str = "snapshot.cft";
/// Op-log file name inside the data dir.
pub const OPLOG_FILE: &str = "oplog.cft";

/// What `Store::open` recovered from disk.
#[derive(Clone, Debug)]
pub struct Recovery {
    /// The verified snapshot, if one existed.
    pub snapshot: Option<Snapshot>,
    /// Ops acked after that snapshot, in append order.
    pub ops: Vec<LogOp>,
    /// Bytes of torn tail record truncated off the log (0 = clean).
    pub truncated_bytes: u64,
}

impl Recovery {
    /// The membership epoch to re-admit at: the snapshot's recorded
    /// epoch, overridden by any later `Epoch` record in the log.
    pub fn recorded_epoch(&self) -> Option<u64> {
        let mut epoch = self.snapshot.as_ref().map(|s| s.partition_epoch);
        for op in &self.ops {
            if let LogOp::Epoch(e) = op {
                epoch = Some(*e);
            }
        }
        epoch
    }

    /// True when there was nothing on disk (first boot with a fresh
    /// data dir).
    pub fn is_empty(&self) -> bool {
        self.snapshot.is_none() && self.ops.is_empty()
    }
}

/// Monotonic durability counters, surfaced under `durability` in
/// `\x01stats`.
#[derive(Clone, Copy, Debug, Default)]
pub struct DurabilityCounters {
    /// Log records appended since boot.
    pub log_records_appended: u64,
    /// fsync calls issued for the log since boot.
    pub log_fsyncs: u64,
    /// Ops replayed from the log at startup.
    pub log_replayed: u64,
    /// Torn-tail bytes truncated at startup (0 = clean shutdown).
    pub log_truncated_bytes: u64,
    /// Snapshots written since boot (startup recovery not included).
    pub snapshots_written: u64,
    /// Whether startup loaded a snapshot.
    pub snapshot_loaded: bool,
    /// Ops appended since the last snapshot (drives auto-snapshot).
    pub ops_since_snapshot: u64,
}

/// Data-dir handle: owns the open op log and the snapshot path, tracks
/// the counters, and applies the snapshot-interval policy.
#[derive(Debug)]
pub struct Store {
    dir: PathBuf,
    log: OpLog,
    /// Cut a snapshot automatically after this many acked ops
    /// (0 disables auto-snapshotting; `\x01snapshot` still works).
    snapshot_interval_ops: u64,
    replayed: u64,
    truncated_bytes: u64,
    snapshot_loaded: bool,
    snapshots_written: u64,
    ops_since_snapshot: u64,
}

impl Store {
    /// Open (creating if needed) the data dir, verify + load the
    /// snapshot if present, replay the op log (truncating a torn tail,
    /// refusing mid-log corruption loudly), and return the append
    /// handle plus everything recovered. A corrupt snapshot or corrupt
    /// log body is a hard error — the caller must refuse to start
    /// rather than serve silently wrong state.
    pub fn open(
        dir: &Path,
        fsync_every: u32,
        snapshot_interval_ops: u64,
    ) -> io::Result<(Store, Recovery)> {
        fs::create_dir_all(dir)?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let snapshot = match snapshot::load(&snap_path) {
            Ok(s) => Some(s),
            Err(e) if e.kind() == io::ErrorKind::NotFound => None,
            Err(e) => {
                return Err(io::Error::new(
                    e.kind(),
                    format!(
                        "refusing to start from {}: {e}",
                        snap_path.display()
                    ),
                ))
            }
        };
        // A crash between the tmp fsync and the rename leaves a stale
        // staging file; it was never the authoritative snapshot, so
        // drop it.
        let _ = fs::remove_file(snapshot::tmp_path(&snap_path));
        let (log, replay) = OpLog::open(&dir.join(OPLOG_FILE), fsync_every)?;
        let truncated_bytes = match replay.tail {
            TailOutcome::Clean => 0,
            TailOutcome::Truncated { dropped_bytes } => dropped_bytes,
        };
        let store = Store {
            dir: dir.to_path_buf(),
            log,
            snapshot_interval_ops,
            replayed: replay.ops.len() as u64,
            truncated_bytes,
            snapshot_loaded: snapshot.is_some(),
            snapshots_written: 0,
            ops_since_snapshot: replay.ops.len() as u64,
        };
        let recovery =
            Recovery { snapshot, ops: replay.ops, truncated_bytes };
        Ok((store, recovery))
    }

    /// Append one acked op to the log. With `fsync_every = 1` the
    /// record is durable when this returns — the caller acks the
    /// client only on `Ok`.
    pub fn record(&mut self, op: &LogOp) -> io::Result<()> {
        self.log.append(op)?;
        self.ops_since_snapshot += 1;
        Ok(())
    }

    /// True when the auto-snapshot interval has been reached.
    pub fn should_snapshot(&self) -> bool {
        self.snapshot_interval_ops > 0
            && self.ops_since_snapshot >= self.snapshot_interval_ops
    }

    /// Cut a new snapshot of `entries` at `partition_epoch`, atomically
    /// replacing the old one, then truncate the op log (its records are
    /// now folded into the snapshot).
    pub fn write_snapshot(
        &mut self,
        partition_epoch: u64,
        entries: Vec<(u64, u32, Vec<EntityAddress>)>,
    ) -> io::Result<()> {
        // Any batched-but-unsynced records must hit disk before the log
        // is truncated out from under them.
        self.log.sync()?;
        let snap = Snapshot { partition_epoch, entries };
        snapshot::write_atomic(&self.dir.join(SNAPSHOT_FILE), &snap)?;
        self.log.reset()?;
        self.snapshots_written += 1;
        self.ops_since_snapshot = 0;
        Ok(())
    }

    /// The data directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Current durability counters (for `\x01stats`).
    pub fn counters(&self) -> DurabilityCounters {
        DurabilityCounters {
            log_records_appended: self.log.appended,
            log_fsyncs: self.log.fsyncs,
            log_replayed: self.replayed,
            log_truncated_bytes: self.truncated_bytes,
            snapshots_written: self.snapshots_written,
            snapshot_loaded: self.snapshot_loaded,
            ops_since_snapshot: self.ops_since_snapshot,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("cft-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn ins(name: &str, tree: u32, node: u32) -> LogOp {
        LogOp::Insert {
            entity: name.to_string(),
            addr: EntityAddress::new(tree, node),
        }
    }

    #[test]
    fn fresh_dir_recovers_empty() {
        let dir = tmp("fresh");
        let (store, rec) = Store::open(&dir, 1, 0).unwrap();
        assert!(rec.is_empty());
        assert_eq!(rec.recorded_epoch(), None);
        assert!(!store.counters().snapshot_loaded);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn records_survive_reopen() {
        let dir = tmp("reopen");
        {
            let (mut store, _) = Store::open(&dir, 1, 0).unwrap();
            store.record(&ins("alpha", 0, 1)).unwrap();
            store.record(&LogOp::Epoch(3)).unwrap();
            store.record(&LogOp::Delete { entity: "beta".into() }).unwrap();
        }
        let (store, rec) = Store::open(&dir, 1, 0).unwrap();
        assert_eq!(rec.ops.len(), 3);
        assert_eq!(rec.recorded_epoch(), Some(3));
        assert_eq!(store.counters().log_replayed, 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_folds_log_and_epoch_precedence_holds() {
        let dir = tmp("fold");
        {
            let (mut store, _) = Store::open(&dir, 1, 0).unwrap();
            store.record(&ins("alpha", 0, 1)).unwrap();
            store
                .write_snapshot(5, vec![(42, 7, vec![EntityAddress::new(0, 1)])])
                .unwrap();
            // post-snapshot ops land in the (fresh) log
            store.record(&LogOp::Epoch(6)).unwrap();
        }
        let (_, rec) = Store::open(&dir, 1, 0).unwrap();
        let snap = rec.snapshot.as_ref().expect("snapshot loaded");
        assert_eq!(snap.partition_epoch, 5);
        assert_eq!(snap.entries.len(), 1);
        assert_eq!(rec.ops, vec![LogOp::Epoch(6)]);
        // a later Epoch log record overrides the snapshot's epoch
        assert_eq!(rec.recorded_epoch(), Some(6));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn should_snapshot_follows_interval() {
        let dir = tmp("interval");
        let (mut store, _) = Store::open(&dir, 1, 2).unwrap();
        assert!(!store.should_snapshot());
        store.record(&ins("a", 0, 0)).unwrap();
        assert!(!store.should_snapshot());
        store.record(&ins("b", 0, 1)).unwrap();
        assert!(store.should_snapshot());
        store.write_snapshot(0, vec![]).unwrap();
        assert!(!store.should_snapshot(), "counter resets after snapshot");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn interval_zero_never_auto_snapshots() {
        let dir = tmp("nointerval");
        let (mut store, _) = Store::open(&dir, 1, 0).unwrap();
        for i in 0..100 {
            store.record(&ins(&format!("e{i}"), 0, i)).unwrap();
        }
        assert!(!store.should_snapshot());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_refuses_to_open() {
        let dir = tmp("corrupt");
        {
            let (mut store, _) = Store::open(&dir, 1, 0).unwrap();
            store.write_snapshot(1, vec![(1, 1, vec![])]).unwrap();
        }
        let snap_path = dir.join(SNAPSHOT_FILE);
        let mut bytes = fs::read(&snap_path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        fs::write(&snap_path, &bytes).unwrap();
        let err = Store::open(&dir, 1, 0).unwrap_err();
        assert!(err.to_string().contains("refusing to start"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_tmp_staging_file_is_dropped() {
        let dir = tmp("staletmp");
        fs::create_dir_all(&dir).unwrap();
        let stale = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
        fs::write(&stale, b"half a snapshot").unwrap();
        let (_, rec) = Store::open(&dir, 1, 0).unwrap();
        assert!(rec.is_empty(), "stale tmp must not be treated as state");
        assert!(!stale.exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
