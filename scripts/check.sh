#!/usr/bin/env bash
# Tier-1 verify + lint gate. Run from the repository root:
#
#   scripts/check.sh           # fmt + clippy + build + test
#   scripts/check.sh --fast    # skip the release build
#
# CI runs exactly this script; keep it in sync with
# .github/workflows/ci.yml and ROADMAP.md ("Tier-1 verify").
set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "==> cargo fmt --check"
cargo fmt --all --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

# Docs are a first-class deliverable (README.md + docs/PROTOCOL.md +
# docs/OPERATIONS.md + rustdoc): broken intra-doc links or malformed
# rustdoc fail the gate.
echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --workspace --quiet

# Module docs carry runnable `# Examples` (router/{ring,pool,health,
# backend,metrics}.rs especially); run them explicitly so a drifted
# example fails the gate even if a harness config ever stops `cargo
# test` from picking doctests up implicitly.
echo "==> cargo test --doc"
cargo test --doc --quiet

if [[ "$fast" == 0 ]]; then
  echo "==> cargo build --release"
  cargo build --release

  # Examples are the documented entry points (serve_requests drives the
  # router mode); build them all so the multi-process serving path can't
  # silently rot out of the default build graph.
  echo "==> cargo build --release --examples"
  cargo build --release --examples

  # The harness=false benches are not part of the test build, so without
  # this they can bit-rot silently; --no-run compiles them without
  # running (benches/* are long-running and not pass/fail gates).
  echo "==> cargo bench --no-run"
  cargo bench --no-run
fi

# The full suite includes tests/router_integration.rs (real TCP
# backends in-process — the multi-process serving path); cargo reports
# failing test names, so no separate named run is needed.
echo "==> cargo test -q"
cargo test -q

echo "OK"
