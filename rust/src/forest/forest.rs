//! The entity forest: all trees plus the shared interner. This is the
//! knowledge base every retrieval algorithm searches; the Cuckoo Filter
//! indexes *addresses into this structure*.

use std::collections::HashMap;

use crate::forest::address::EntityAddress;
use crate::forest::interner::{EntityId, Interner};
use crate::forest::tree::{NodeIdx, Tree};

/// Forest of entity trees with the shared entity interner.
#[derive(Clone, Debug, Default)]
pub struct Forest {
    trees: Vec<Tree>,
    interner: Interner,
}

/// Shape statistics (logged by builders, asserted by tests).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ForestStats {
    pub trees: usize,
    pub nodes: usize,
    pub distinct_entities: usize,
    pub max_depth: u32,
    pub total_leaves: usize,
}

impl Forest {
    /// New empty forest.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern an entity name.
    pub fn intern(&mut self, name: &str) -> EntityId {
        self.interner.intern(name)
    }

    /// Entity id of a name if known.
    pub fn entity_id(&self, name: &str) -> Option<EntityId> {
        self.interner.get(name)
    }

    /// Name of an entity id.
    pub fn entity_name(&self, id: EntityId) -> &str {
        self.interner.name(id)
    }

    /// The interner.
    pub fn interner(&self) -> &Interner {
        &self.interner
    }

    /// Add a tree, returning its index.
    pub fn add_tree(&mut self, tree: Tree) -> u32 {
        self.trees.push(tree);
        (self.trees.len() - 1) as u32
    }

    /// Tree accessor.
    pub fn tree(&self, idx: u32) -> &Tree {
        &self.trees[idx as usize]
    }

    /// All trees.
    pub fn trees(&self) -> &[Tree] {
        &self.trees
    }

    /// Number of trees.
    pub fn len(&self) -> usize {
        self.trees.len()
    }

    /// True if no trees.
    pub fn is_empty(&self) -> bool {
        self.trees.is_empty()
    }

    /// Total node count across trees.
    pub fn total_nodes(&self) -> usize {
        self.trees.iter().map(Tree::len).sum()
    }

    /// Entity at an address.
    pub fn entity_at(&self, addr: EntityAddress) -> EntityId {
        self.tree(addr.tree).entity(addr.node as NodeIdx)
    }

    /// Exhaustively scan the forest for every address of `entity`
    /// (ground truth used to validate retrievers and to build the CF).
    pub fn scan_addresses(&self, entity: EntityId) -> Vec<EntityAddress> {
        let mut out = Vec::new();
        for (t, tree) in self.trees.iter().enumerate() {
            for idx in tree.indices() {
                if tree.entity(idx) == entity {
                    out.push(EntityAddress::new(t as u32, idx));
                }
            }
        }
        out
    }

    /// Build the full entity -> addresses table in one forest pass.
    pub fn address_table(&self) -> HashMap<EntityId, Vec<EntityAddress>> {
        let mut table: HashMap<EntityId, Vec<EntityAddress>> = HashMap::new();
        for (t, tree) in self.trees.iter().enumerate() {
            for idx in tree.indices() {
                table
                    .entry(tree.entity(idx))
                    .or_default()
                    .push(EntityAddress::new(t as u32, idx));
            }
        }
        table
    }

    /// Shape statistics.
    pub fn stats(&self) -> ForestStats {
        ForestStats {
            trees: self.trees.len(),
            nodes: self.total_nodes(),
            distinct_entities: self.interner.len(),
            max_depth: self.trees.iter().map(Tree::max_depth).max().unwrap_or(0),
            total_leaves: self.trees.iter().map(Tree::leaves).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_forest() -> Forest {
        let mut f = Forest::new();
        let root = f.intern("hospital");
        let card = f.intern("cardiology");
        let icu = f.intern("icu");
        let mut t0 = Tree::with_root(root);
        let c = t0.add_child(0, card);
        t0.add_child(c, icu);
        f.add_tree(t0);
        let mut t1 = Tree::with_root(f.intern("clinic"));
        t1.add_child(0, card); // cardiology appears in both trees
        f.add_tree(t1);
        f
    }

    #[test]
    fn scan_finds_all_occurrences() {
        let f = sample_forest();
        let card = f.entity_id("cardiology").unwrap();
        let addrs = f.scan_addresses(card);
        assert_eq!(addrs.len(), 2);
        assert_eq!(addrs[0].tree, 0);
        assert_eq!(addrs[1].tree, 1);
    }

    #[test]
    fn address_table_matches_scan() {
        let f = sample_forest();
        let table = f.address_table();
        for (id, _) in f.interner().iter() {
            assert_eq!(table.get(&id).cloned().unwrap_or_default(), f.scan_addresses(id));
        }
    }

    #[test]
    fn entity_at_roundtrip() {
        let f = sample_forest();
        let icu = f.entity_id("icu").unwrap();
        let addr = f.scan_addresses(icu)[0];
        assert_eq!(f.entity_at(addr), icu);
    }

    #[test]
    fn stats_counts() {
        let f = sample_forest();
        let s = f.stats();
        assert_eq!(s.trees, 2);
        assert_eq!(s.nodes, 5);
        assert_eq!(s.distinct_entities, 4);
        assert_eq!(s.max_depth, 2);
    }
}
