//! Unified metrics registry: named counters, gauges and log-linear
//! latency histograms behind lock-free handles, rendered on demand as
//! Prometheus text exposition.
//!
//! This replaces the hand-rolled percentile plumbing that used to be
//! duplicated across `coordinator/metrics.rs` and `router/metrics.rs`:
//! both sinks now allocate their series here and keep only their
//! domain-specific snapshot shapes (the `\x01stats` JSON contracts).
//!
//! Design points:
//!
//! * **Handles are cheap.** [`Counter`], [`Gauge`] and [`Histogram`]
//!   are `Arc`ed atomics; recording is a relaxed `fetch_add` (three of
//!   them for a histogram), safe on any hot path.
//! * **Registration is idempotent.** Asking for an existing name
//!   returns the existing handle, so construction order never matters.
//!   Re-registering a name as a *different* kind is a programming
//!   error and panics.
//! * **Same buckets as `util/stats.rs`.** The histogram uses the
//!   identical log-spaced layout (base 100 ns, growth 1.5, 64
//!   buckets), so quantiles reported through `\x01stats` are unchanged
//!   to the digit from the pre-registry code.
//!
//! # Examples
//!
//! ```
//! use cft_rag::obs::Registry;
//!
//! let reg = Registry::new();
//! let hits = reg.counter("cache_hits_total", "cache hits");
//! hits.inc();
//! let lat = reg.histogram("request_seconds", "request latency");
//! lat.record(0.003);
//! let text = reg.render();
//! assert!(text.contains("# TYPE cache_hits_total counter"));
//! assert!(text.contains("request_seconds_bucket"));
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Duration;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};

/// Number of log-spaced histogram buckets (matches `util/stats.rs`).
pub const HIST_BUCKETS: usize = 64;
/// Lower edge of bucket 0 in seconds: 100 ns (matches `util/stats.rs`).
pub const HIST_BASE: f64 = 1e-7;
/// Geometric growth factor between buckets (matches `util/stats.rs`).
pub const HIST_GROWTH: f64 = 1.5;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// New counter at zero.
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time value (f64 bits in an atomic word).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// New gauge at zero.
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    /// Overwrite the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lock-free log-linear latency histogram.
///
/// Bucket `i` covers `[HIST_BASE * HIST_GROWTH^i, HIST_BASE *
/// HIST_GROWTH^(i+1))` seconds; observations above the last bucket land
/// in an overflow cell (reported as the `+Inf` bucket). The index math
/// and quantile convention (upper bucket edge) replicate
/// `util::stats::LatencyHistogram` exactly, so callers migrating off
/// the mutex-guarded histogram see identical numbers.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    overflow: AtomicU64,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// New empty histogram covering ~100 ns ..= ~3000 s.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            overflow: AtomicU64::new(0),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one observation in seconds.
    pub fn record(&self, secs: f64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        let ns = (secs * 1e9).clamp(0.0, u64::MAX as f64) as u64;
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        if secs < HIST_BASE {
            self.buckets[0].fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = ((secs / HIST_BASE).ln() / HIST_GROWTH.ln()) as usize;
        if idx < HIST_BUCKETS {
            self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        } else {
            self.overflow.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one observation given as a [`Duration`].
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations in seconds.
    pub fn sum(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Mean observation in seconds (0 if empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum() / n as f64 }
    }

    /// Approximate quantile (upper bucket edge), in seconds. Same
    /// convention as `util::stats::LatencyHistogram::quantile`:
    /// observations past the last bucket push the result to infinity.
    pub fn quantile(&self, q: f64) -> f64 {
        let count = self.count();
        if count == 0 {
            return 0.0;
        }
        let target = (q * count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return HIST_BASE * HIST_GROWTH.powi(i as i32 + 1);
            }
        }
        f64::INFINITY
    }

    /// Upper edge of bucket `i`, in seconds.
    pub fn bucket_upper(i: usize) -> f64 {
        HIST_BASE * HIST_GROWTH.powi(i as i32 + 1)
    }

    /// Per-bucket counts plus the overflow cell (monitoring-grade: the
    /// loads are not a consistent cut against concurrent writers).
    pub fn bucket_counts(&self) -> ([u64; HIST_BUCKETS], u64) {
        let counts = std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed));
        (counts, self.overflow.load(Ordering::Relaxed))
    }
}

/// One registered series.
#[derive(Clone, Debug)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug)]
struct Entry {
    help: String,
    metric: Metric,
}

/// Named-series registry; the process-wide metric vocabulary.
///
/// Lookup takes a short mutex on the name map; the returned handles
/// are lock-free, so callers register once at construction and record
/// through the handle on hot paths.
#[derive(Debug, Default)]
pub struct Registry {
    series: Mutex<BTreeMap<String, Entry>>,
}

/// `debug_assert` helper: Prometheus metric-name grammar.
fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

impl Registry {
    /// New empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn get_or_insert(&self, name: &str, help: &str, make: impl FnOnce() -> Metric) -> Metric {
        debug_assert!(valid_name(name), "invalid metric name {name:?}");
        let mut series = self.series.lock().unwrap();
        if let Some(entry) = series.get(name) {
            let existing = entry.metric.clone();
            let wanted = make();
            assert_eq!(
                existing.kind(),
                wanted.kind(),
                "metric {name:?} re-registered as a different kind"
            );
            return existing;
        }
        let metric = make();
        series.insert(
            name.to_string(),
            Entry { help: help.to_string(), metric: metric.clone() },
        );
        metric
    }

    /// Register (or fetch) a counter.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        match self.get_or_insert(name, help, || Metric::Counter(Arc::new(Counter::new()))) {
            Metric::Counter(c) => c,
            _ => unreachable!("kind checked in get_or_insert"),
        }
    }

    /// Register (or fetch) a gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        match self.get_or_insert(name, help, || Metric::Gauge(Arc::new(Gauge::new()))) {
            Metric::Gauge(g) => g,
            _ => unreachable!("kind checked in get_or_insert"),
        }
    }

    /// Register (or fetch) a histogram.
    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        match self.get_or_insert(name, help, || Metric::Histogram(Arc::new(Histogram::new()))) {
            Metric::Histogram(h) => h,
            _ => unreachable!("kind checked in get_or_insert"),
        }
    }

    /// Render every series as Prometheus text exposition (version
    /// 0.0.4): `# HELP`/`# TYPE` per series, cumulative histogram
    /// buckets terminated by `le="+Inf"`, plus `_sum`/`_count`.
    pub fn render(&self) -> String {
        let series = self.series.lock().unwrap();
        let mut out = String::new();
        for (name, entry) in series.iter() {
            let help = entry.help.replace('\\', "\\\\").replace('\n', "\\n");
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {}", entry.metric.kind());
            match &entry.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", g.get());
                }
                Metric::Histogram(h) => {
                    let (counts, overflow) = h.bucket_counts();
                    let mut acc = 0u64;
                    for (i, c) in counts.iter().enumerate() {
                        if *c == 0 {
                            continue;
                        }
                        acc += c;
                        let _ = writeln!(
                            out,
                            "{name}_bucket{{le=\"{:.6e}\"}} {acc}",
                            Histogram::bucket_upper(i)
                        );
                    }
                    acc += overflow;
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {acc}");
                    let _ = writeln!(out, "{name}_sum {}", h.sum());
                    let _ = writeln!(out, "{name}_count {}", h.count());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::LatencyHistogram;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("c_total", "a counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = reg.gauge("g", "a gauge");
        g.set(2.5);
        assert!((g.get() - 2.5).abs() < 1e-12);
        // idempotent registration returns the same underlying series
        reg.counter("c_total", "a counter").inc();
        assert_eq!(c.get(), 6);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let reg = Registry::new();
        reg.counter("x", "as counter");
        reg.gauge("x", "as gauge");
    }

    #[test]
    fn histogram_quantiles_match_legacy_latency_histogram() {
        let reg = Registry::new();
        let h = reg.histogram("lat_seconds", "latency");
        let mut legacy = LatencyHistogram::new();
        for i in 1..=500u32 {
            let secs = 1e-5 * i as f64;
            h.record(secs);
            legacy.record(secs);
        }
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(
                h.quantile(q),
                legacy.quantile(q),
                "quantile {q} diverged from util::stats"
            );
        }
        assert_eq!(h.count(), legacy.count());
        assert!((h.mean() - legacy.mean()).abs() < 1e-6);
    }

    #[test]
    fn histogram_handles_extremes() {
        let h = Histogram::new();
        h.record(0.0); // below base: bucket 0
        h.record(-1.0); // negative: bucket 0, sum clamped at 0
        h.record(1e9); // far past the last bucket: overflow
        assert_eq!(h.count(), 3);
        let (counts, overflow) = h.bucket_counts();
        assert_eq!(counts[0], 2);
        assert_eq!(overflow, 1);
        assert_eq!(h.quantile(0.99), f64::INFINITY);
    }

    #[test]
    fn render_is_lintable_exposition() {
        let reg = Registry::new();
        reg.counter("reqs_total", "requests").add(3);
        reg.gauge("depth", "queue depth").set(1.0);
        let h = reg.histogram("lat_seconds", "latency");
        h.record(1e-4);
        h.record(1e-2);
        h.record(5e3); // overflow
        let text = reg.render();
        assert!(text.contains("# HELP reqs_total requests"));
        assert!(text.contains("# TYPE reqs_total counter"));
        assert!(text.contains("reqs_total 3"));
        assert!(text.contains("# TYPE depth gauge"));
        assert!(text.contains("# TYPE lat_seconds histogram"));
        // buckets are cumulative and +Inf-terminated with the count
        let mut last = 0u64;
        let mut inf_seen = false;
        for line in text.lines().filter(|l| l.starts_with("lat_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "buckets must be cumulative: {line}");
            last = v;
            inf_seen = line.contains("le=\"+Inf\"");
        }
        assert!(inf_seen, "last bucket must be +Inf");
        assert_eq!(last, 3, "+Inf bucket equals the observation count");
        assert!(text.contains("lat_seconds_count 3"));
    }

    #[test]
    fn empty_histogram_still_renders_inf_bucket() {
        let reg = Registry::new();
        reg.histogram("idle_seconds", "never recorded");
        let text = reg.render();
        assert!(text.contains("idle_seconds_bucket{le=\"+Inf\"} 0"));
        assert!(text.contains("idle_seconds_count 0"));
    }

    #[test]
    fn name_grammar() {
        assert!(valid_name("a_b:c9"));
        assert!(!valid_name(""));
        assert!(!valid_name("9a"));
        assert!(!valid_name("a-b"));
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        use crate::sync::thread;
        let reg = Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let reg = Arc::clone(&reg);
            handles.push(thread::spawn(move || {
                let c = reg.counter("shared_total", "shared");
                let h = reg.histogram("shared_seconds", "shared");
                for _ in 0..1000 {
                    c.inc();
                    h.record(1e-3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.counter("shared_total", "shared").get(), 4000);
        assert_eq!(reg.histogram("shared_seconds", "shared").count(), 4000);
    }
}
