"""AOT lowering path tests: HLO text integrity + manifest contract.

Guards the build-path bug class that silently zeroes weights: the default
HLO printer elides >KiB constants to `{...}`, which the Rust-side text
parser reads back as zeros (caught live during bring-up — see
EXPERIMENTS.md §Perf L2 notes).
"""

import json

import jax

from compile import aot, model


def test_hlo_text_materializes_large_constants():
    for name, (fn, specs) in aot.ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs())
        text = aot.to_hlo_text(lowered)
        assert "{...}" not in text, f"{name}: constants elided"
        assert text.startswith("HloModule"), f"{name}: not HLO text"


def test_embed_hlo_contains_weight_vectors():
    lowered = jax.jit(model.embed).lower(*model.embed_specs())
    text = aot.to_hlo_text(lowered)
    # the FREQ vector's first element must appear literally in the text
    first_freq = float(model.FREQ[0])
    assert f"{first_freq:.6g}"[:6] in text.replace(" ", ""), (
        "FREQ constants not materialized in HLO text"
    )


def test_manifest_matches_model_constants():
    m = aot.build_manifest()
    assert m["embed_dim"] == model.EMBED_DIM
    assert m["max_tokens"] == model.MAX_TOKENS
    assert m["shard_docs"] == model.SHARD_DOCS
    assert m["max_facts"] == model.MAX_FACTS
    assert m["batch"] == model.BATCH
    assert m["pad_id"] == model.PAD_ID
    # round-trips through json
    assert json.loads(json.dumps(m)) == m
    # every artifact declares its input shapes
    for name in ("embed", "score", "rank"):
        inputs = m["artifacts"][name]["inputs"]
        assert all(len(i["shape"]) >= 1 for i in inputs)


def test_artifact_entry_shapes():
    m = aot.build_manifest()
    assert m["artifacts"]["embed"]["inputs"][0]["shape"] == [
        model.BATCH,
        model.MAX_TOKENS,
    ]
    assert m["artifacts"]["embed"]["inputs"][0]["dtype"] == "int32"
    assert m["artifacts"]["score"]["inputs"][1]["shape"] == [
        model.SHARD_DOCS,
        model.EMBED_DIM,
    ]
    assert m["artifacts"]["rank"]["inputs"][2]["shape"] == [model.BATCH]
