//! Reproduces **Table 1**: retrieval time + accuracy for tree counts
//! {50, 300, 600} across Naive / BF / BF2 / CF T-RAG.
//!
//! Run: `cargo bench --bench table1` (flags: --trees, --queries, --repeats)
//! Writes `results/table1.csv`.

use cft_rag::bench::experiments::{table1, ExperimentConfig};
use cft_rag::util::cli::{spec, Args};

fn main() {
    let args = Args::from_env(vec![
        spec("trees", "comma-separated tree counts", Some("50,300,600"), false),
        spec("queries", "queries per workload", Some("100"), false),
        spec("repeats", "timed repeats", Some("10"), false),
        spec("out", "CSV output path", Some("results/table1.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let cfg = ExperimentConfig {
        queries: args.num_or("queries", 100),
        repeats: args.num_or("repeats", 10),
        ..ExperimentConfig::default()
    };
    let trees: Vec<usize> = args.list_or("trees", &[50, 300, 600]);
    let csv = table1(cfg, &trees);
    let out = args.str_or("out", "results/table1.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");
}
