"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: no Pallas, no tiling, just the
mathematical definition. pytest/hypothesis sweeps shapes and dtypes and
asserts the kernels match these within tolerance.
"""

import jax.numpy as jnp


def similarity_scores_ref(q, docs):
    """Similarity scores between query vectors and document vectors.

    Args:
      q:    [B, D] float — (normalized) query embeddings.
      docs: [N, D] float — (normalized) document embeddings.

    Returns:
      [B, N] float32 — dot-product scores (cosine if inputs normalized).
    """
    return jnp.dot(q.astype(jnp.float32), docs.astype(jnp.float32).T)


def attention_weights_ref(q, keys, lens):
    """Masked single-head attention weights of each query over its facts.

    Args:
      q:    [B, D] float — per-request query embedding.
      keys: [B, L, D] float — per-request fact-key matrix (zero padded).
      lens: [B] int32 — number of valid facts per request (<= L).

    Returns:
      [B, L] float32 — softmax(q . K^T / sqrt(D)) with positions >= lens
      masked to exactly 0. Rows with lens == 0 return all zeros.
    """
    q = q.astype(jnp.float32)
    keys = keys.astype(jnp.float32)
    d = q.shape[-1]
    logits = jnp.einsum("bd,bld->bl", q, keys) / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(keys.shape[1])[None, :] < lens[:, None]
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = jnp.where(mask, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    return jnp.where(denom > 0.0, w / jnp.maximum(denom, 1e-30), 0.0)


def layer_norm_ref(x, gamma, beta, eps=1e-5):
    """Layer norm over the last axis.

    Args:
      x:     [B, D] float.
      gamma: [D] float — scale.
      beta:  [D] float — shift.

    Returns:
      [B, D] float32.
    """
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
