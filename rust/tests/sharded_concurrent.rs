//! Multi-thread smoke tests for the sharded Cuckoo retrieval subsystem:
//! concurrent lookups racing maintenance and writers, and agreement with
//! the unsharded retriever. These are scheduling-dependent smoke tests —
//! they assert invariants (no lost entries, no torn address lists, no
//! deadlock), not timings.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cft_rag::filter::cuckoo::CuckooConfig;
use cft_rag::filter::fingerprint::entity_key;
use cft_rag::filter::sharded::ShardedCuckooFilter;
use cft_rag::forest::EntityAddress;
use cft_rag::retrieval::cuckoo_rag::CuckooTRag;
use cft_rag::retrieval::sharded_rag::ShardedCuckooTRag;
use cft_rag::retrieval::{ConcurrentRetriever, Retriever};
use cft_rag::util::rng::Rng;

fn key(i: u64) -> u64 {
    entity_key(&format!("smoke-{i}"))
}

fn addrs(i: u64) -> Vec<EntityAddress> {
    (0..(i % 5 + 1) as u32)
        .map(|j| EntityAddress::new(i as u32, j))
        .collect()
}

/// A returned list is valid if it is `addrs(i)` — or the complete list
/// of a fingerprint-colliding entity (the paper's §4.5.1 "shadowing"
/// error mode, rare but legitimate). Both are internally consistent;
/// a *torn* concurrent read would be neither.
fn valid_list(i: u64, out: &[EntityAddress]) -> bool {
    if out == addrs(i) {
        return true;
    }
    !out.is_empty() && out == addrs(out[0].tree as u64)
}

/// Readers hammer lookups while a maintainer thread re-sorts buckets:
/// every lookup must keep returning the exact address list.
#[test]
fn lookups_race_maintain_without_loss() {
    let cf = Arc::new(ShardedCuckooFilter::new(
        CuckooConfig { initial_buckets: 256, ..CuckooConfig::default() },
        8,
    ));
    let n = 4000u64;
    for i in 0..n {
        assert!(cf.insert(key(i), &addrs(i)));
    }

    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let cf = &cf;
            let stop = &stop;
            s.spawn(move || {
                let mut rng = Rng::new(0xD0 + t);
                let mut out = Vec::with_capacity(8);
                while !stop.load(Ordering::Relaxed) {
                    let i = rng.below(n);
                    out.clear();
                    assert!(cf.lookup_into(key(i), &mut out), "lost {i}");
                    assert!(valid_list(i, &out), "torn read for {i}: {out:?}");
                }
            });
        }
        // maintainer: many write-locked re-sorts while readers run; the
        // extra lookups keep buckets dirty so each pass does real work
        let mut out = Vec::with_capacity(8);
        for round in 0..200u64 {
            for i in 0..20 {
                out.clear();
                cf.lookup_into(key((round * 20 + i) % n), &mut out);
            }
            cf.maintain();
        }
        stop.store(true, Ordering::Relaxed);
    });

    // post-race sweep: nothing lost, nothing torn
    let mut out = Vec::with_capacity(8);
    for i in 0..n {
        out.clear();
        assert!(cf.lookup_into(key(i), &mut out), "lost {i} after race");
        assert!(valid_list(i, &out), "corrupted {i} after race");
    }
    assert!(cf.stats().lookups > 0);
}

/// A writer inserts and deletes its own key range while readers verify a
/// stable range; reader keys must never disappear or change.
#[test]
fn writer_churn_does_not_disturb_readers() {
    let cf = Arc::new(ShardedCuckooFilter::new(
        // small: writer churn forces in-shard expansions under the race
        CuckooConfig { initial_buckets: 16, ..CuckooConfig::default() },
        4,
    ));
    let stable = 1000u64;
    for i in 0..stable {
        assert!(cf.insert(key(i), &addrs(i)));
    }

    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..3u64 {
            let cf = &cf;
            let done = &done;
            s.spawn(move || {
                let mut rng = Rng::new(0xFEED ^ t);
                let mut out = Vec::with_capacity(8);
                while !done.load(Ordering::Relaxed) {
                    let i = rng.below(stable);
                    out.clear();
                    assert!(cf.lookup_into(key(i), &mut out), "stable key {i} lost");
                    assert!(valid_list(i, &out), "torn read for {i}: {out:?}");
                }
            });
        }
        // churn writer: volatile keys in a disjoint range
        for round in 0..30u64 {
            for i in 0..200u64 {
                let id = 1_000_000 + round * 200 + i;
                assert!(cf.insert(key(id), &addrs(id)));
            }
            for i in 0..200u64 {
                let id = 1_000_000 + round * 200 + i;
                assert!(cf.delete(key(id)));
            }
        }
        done.store(true, Ordering::Relaxed);
    });

    assert_eq!(cf.len(), stable as usize, "only stable keys remain");
}

/// Readers keep verifying a stable key range while a writer drives every
/// shard through incremental doubling migrations (tiny 2-bucket steps,
/// so shards spend most of the race serving from two table generations).
/// Every lookup during migration must succeed and return an untorn list
/// — the correctness half of the PR-2 reader-stall scenario; the latency
/// half (no reader waits for a full-table migration) is measured by
/// `benches/concurrent.rs`.
#[test]
fn readers_race_incremental_expansion_without_loss() {
    let cf = Arc::new(ShardedCuckooFilter::new(
        CuckooConfig {
            initial_buckets: 64,
            migration_step_buckets: 2,
            ..CuckooConfig::default()
        },
        4,
    ));
    let stable = 200u64;
    for i in 0..stable {
        assert!(cf.insert(key(i), &addrs(i)));
    }

    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..3u64 {
            let cf = &cf;
            let done = &done;
            s.spawn(move || {
                let mut rng = Rng::new(0x0E5C_A1A7 ^ t);
                let mut out = Vec::with_capacity(8);
                while !done.load(Ordering::Relaxed) {
                    let i = rng.below(stable);
                    out.clear();
                    assert!(
                        cf.lookup_into(key(i), &mut out),
                        "stable key {i} lost during incremental expansion"
                    );
                    assert!(valid_list(i, &out), "torn read for {i}: {out:?}");
                }
            });
        }
        // writer: fresh volatile keys every round force doublings in
        // every shard on the first round; later rounds churn the grown
        // tables (deletes mid-migration included)
        for round in 0..10u64 {
            for i in 0..500u64 {
                let id = 2_000_000 + round * 500 + i;
                assert!(cf.insert(key(id), &addrs(id)));
            }
            for i in 0..500u64 {
                let id = 2_000_000 + round * 500 + i;
                assert!(cf.delete(key(id)));
            }
        }
        done.store(true, Ordering::Relaxed);
    });

    assert!(
        cf.stats().expansions >= 4,
        "every shard should have grown: {} expansions",
        cf.stats().expansions
    );
    // drain any still-pending migrations, then the full sweep
    cf.maintain();
    assert!(!cf.any_migration_pending());
    let mut out = Vec::with_capacity(8);
    for i in 0..stable {
        out.clear();
        assert!(cf.lookup_into(key(i), &mut out), "lost {i} after the race");
        assert!(valid_list(i, &out), "corrupted {i} after the race");
    }
    assert_eq!(cf.len(), stable as usize, "only stable keys remain");
}

/// Concurrent retrieval through the retriever layer agrees exactly with
/// the single-threaded unsharded retriever.
#[test]
fn sharded_retriever_agrees_with_unsharded_under_threads() {
    use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
    let ds = HospitalDataset::generate(HospitalConfig {
        trees: 20,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let mut plain = CuckooTRag::new(forest.clone());
    let sharded = Arc::new(ShardedCuckooTRag::new(forest.clone(), 8));

    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect();
    // ground truth from the sharded retriever itself, single-threaded:
    // the property under test is that concurrency changes nothing
    let expected: Vec<Vec<EntityAddress>> = names
        .iter()
        .map(|n| {
            let mut a = Vec::new();
            sharded.find_concurrent(n, &mut a);
            a.sort();
            a
        })
        .collect();

    std::thread::scope(|s| {
        for t in 0..4usize {
            let sharded = &sharded;
            let names = &names;
            let expected = &expected;
            s.spawn(move || {
                let mut out = Vec::with_capacity(64);
                for round in 0..50 {
                    let idx = (t * 7 + round * 13) % names.len();
                    out.clear();
                    sharded.find_concurrent(&names[idx], &mut out);
                    out.sort();
                    assert_eq!(out, expected[idx], "{}", names[idx]);
                }
            });
        }
    });

    // cross-design agreement: identical up to the paper's near-zero
    // fingerprint-shadowing rate (§4.5.1), whose incidence depends on
    // bucket layout and so may differ between the two designs
    let mut mismatches = 0usize;
    for (n, want) in names.iter().zip(&expected) {
        let mut a = plain.find(n);
        a.sort();
        if &a != want {
            mismatches += 1;
        }
        assert!(!a.is_empty(), "false negative in plain for {n}");
    }
    assert!(
        mismatches <= 1 + names.len() / 100,
        "designs disagree on {mismatches}/{} entities",
        names.len()
    );
}
