//! English stopword list used by the tokenizer and the NER heuristics.

use std::collections::HashSet;

use once_cell::sync::Lazy;

/// Compact stopword list — function words that never begin or end an
/// entity mention and carry no retrieval signal.
pub static STOPWORDS: Lazy<HashSet<&'static str>> = Lazy::new(|| {
    [
        "a", "an", "the", "and", "or", "but", "of", "in", "on", "at", "to",
        "for", "from", "by", "with", "about", "as", "into", "through",
        "is", "am", "are", "was", "were", "be", "been", "being",
        "do", "does", "did", "have", "has", "had", "having",
        "i", "you", "he", "she", "it", "we", "they", "them", "his", "her",
        "its", "their", "our", "your", "my", "me", "him", "us",
        "this", "that", "these", "those", "which", "who", "whom", "whose",
        "what", "where", "when", "why", "how",
        "not", "no", "nor", "so", "too", "very", "can", "will", "just",
        "should", "would", "could", "may", "might", "must", "shall",
        "there", "here", "then", "than", "also", "such", "each", "both",
        "more", "most", "some", "any", "all", "few", "other", "own", "same",
        "under", "over", "between", "during", "before", "after", "above",
        "below", "again", "further", "once", "only", "now", "while",
        "belongs", "belong", "contains", "contain", "part", "within",
        "department", "unit", "division", "branch", "section", "office",
        "tell", "describe", "explain", "list", "give", "show", "report",
    ]
    .into_iter()
    .collect()
});

/// Is `word` (already lowercased) a stopword?
pub fn is_stopword(word: &str) -> bool {
    STOPWORDS.contains(word)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn common_words_are_stopwords() {
        for w in ["the", "of", "is", "belongs"] {
            assert!(is_stopword(w), "{w}");
        }
    }

    #[test]
    fn content_words_are_not() {
        for w in ["cardiology", "unhcr", "surgery", "geneva"] {
            assert!(!is_stopword(w), "{w}");
        }
    }
}
