//! The reactor's one thin unsafe layer: readiness polling and
//! nonblocking connect, bound directly against the C runtime std
//! already links — **no external crates**.
//!
//! Everything above this module ([`server`](crate::reactor::server),
//! [`client`](crate::reactor::client)) is safe Rust over std types;
//! everything below is the kernel. The surface is deliberately tiny:
//!
//! * [`Poller`] — readiness notification. On Linux it is an `epoll`
//!   instance (level- or edge-triggered per registration, mio-style);
//!   on other unixes it degrades to a `poll(2)` set rebuilt per wait
//!   (level-triggered only — the `edge` flag is advisory there).
//!   Non-unix targets are rejected at compile time: the serving core
//!   is a Linux deployment target and CI runs Linux.
//! * [`Waker`] — cross-thread loop wakeup built from a connected
//!   UDP socket pair (pure std; keeps `eventfd`/pipes out of the
//!   unsafe surface). Sends are coalescible and never block.
//! * [`start_connect`] / [`connect_result`] (Linux) — a nonblocking
//!   TCP connect: `socket(2)` with `SOCK_NONBLOCK`, `connect(2)`
//!   returning `EINPROGRESS`, completion read back with
//!   `getsockopt(SO_ERROR)` once the poller reports writability.
//!
//! Unsafe hygiene matches the crate rule (`lib.rs`): every unsafe
//! block carries a `// SAFETY:` contract, and raw fds are wrapped in
//! owning std types (`OwnedFd`, `TcpStream`) at the earliest possible
//! moment so no code path leaks a descriptor.

use std::io;
use std::time::Duration;

/// One readiness event delivered by [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The registration token (the reactor uses connection/op ids).
    pub token: u64,
    /// Readable (or a peer close flagged via `EPOLLRDHUP`).
    pub readable: bool,
    /// Writable (also how a completed nonblocking connect reports).
    pub writable: bool,
    /// Error/hangup condition on the fd — the owner should attempt IO
    /// and let the resulting `Err`/EOF drive teardown.
    pub broken: bool,
}

/// What a registration wants to hear about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interest {
    /// Readable readiness.
    pub readable: bool,
    /// Writable readiness.
    pub writable: bool,
    /// Edge-triggered delivery (Linux only; the `poll(2)` fallback is
    /// inherently level-triggered and ignores this). The serving core
    /// registers level-triggered and drains to `WouldBlock` anyway, so
    /// the flag is an option, not a correctness requirement.
    pub edge: bool,
}

impl Interest {
    /// Level-triggered read interest.
    pub const READ: Interest =
        Interest { readable: true, writable: false, edge: false };
    /// Level-triggered write interest.
    pub const WRITE: Interest =
        Interest { readable: false, writable: true, edge: false };

    /// Level-triggered read+write interest.
    pub fn read_write() -> Interest {
        Interest { readable: true, writable: true, edge: false }
    }
}

/// Clamp an optional wait budget to the millisecond argument `epoll`/
/// `poll` take: `None` → block forever (-1), sub-millisecond budgets
/// round **up** so a near deadline cannot spin the loop at 0ms.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis();
            let ms = if Duration::from_millis(ms as u64) < d {
                ms + 1
            } else {
                ms
            };
            ms.min(i32::MAX as u128) as i32
        }
    }
}

#[cfg(target_os = "linux")]
pub use linux::{connect_result, start_connect, Poller};

#[cfg(target_os = "linux")]
mod linux {
    use super::{timeout_ms, Event, Interest};
    use std::io;
    use std::net::{SocketAddr, TcpStream};
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
    use std::os::raw::{c_int, c_void};
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLLET: u32 = 1 << 31;

    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    const AF_INET: c_int = 2;
    const AF_INET6: c_int = 10;
    const SOCK_STREAM: c_int = 1;
    const SOCK_NONBLOCK: c_int = 0o4000;
    const SOCK_CLOEXEC: c_int = 0o2000000;
    const SOL_SOCKET: c_int = 1;
    const SO_ERROR: c_int = 4;
    /// Linux `EINPROGRESS` — the expected "connect started" errno of a
    /// nonblocking `connect(2)`.
    const EINPROGRESS: i32 = 115;

    /// Mirror of `struct epoll_event`. On x86-64 the kernel ABI packs
    /// it to 12 bytes (no padding between `events` and the 64-bit
    /// payload); everywhere else natural `repr(C)` layout matches.
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(
            epfd: c_int,
            op: c_int,
            fd: c_int,
            event: *mut EpollEvent,
        ) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn connect(
            fd: c_int,
            addr: *const c_void,
            len: u32,
        ) -> c_int;
        fn getsockopt(
            fd: c_int,
            level: c_int,
            optname: c_int,
            optval: *mut c_void,
            optlen: *mut u32,
        ) -> c_int;
    }

    /// An `epoll` instance. Registration tokens ride in the kernel's
    /// per-fd event payload, so `wait` hands back `(token, readiness)`
    /// pairs with no userspace lookup.
    #[derive(Debug)]
    pub struct Poller {
        epfd: OwnedFd,
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = EPOLLRDHUP;
        if interest.readable {
            m |= EPOLLIN;
        }
        if interest.writable {
            m |= EPOLLOUT;
        }
        if interest.edge {
            m |= EPOLLET;
        }
        m
    }

    fn cvt(rc: c_int) -> io::Result<c_int> {
        if rc < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(rc)
        }
    }

    impl Poller {
        /// A fresh epoll instance (close-on-exec).
        pub fn new() -> io::Result<Poller> {
            // SAFETY: plain syscall, no pointers; the returned fd is
            // immediately wrapped in an OwnedFd so it cannot leak.
            let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            // SAFETY: `fd` is a freshly created, valid, owned epoll fd.
            Ok(Poller { epfd: unsafe { OwnedFd::from_raw_fd(fd) } })
        }

        fn ctl(
            &self,
            op: c_int,
            fd: RawFd,
            interest: Interest,
            token: u64,
        ) -> io::Result<()> {
            let mut ev = EpollEvent { events: mask(interest), data: token };
            // SAFETY: `ev` outlives the call; the kernel copies it.
            cvt(unsafe {
                epoll_ctl(self.epfd.as_raw_fd(), op, fd, &mut ev)
            })?;
            Ok(())
        }

        /// Start watching `fd` under `token`.
        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, interest, token)
        }

        /// Change what an already-registered `fd` wants to hear.
        pub fn reregister(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, interest, token)
        }

        /// Stop watching `fd`. Safe to call with an fd the kernel
        /// already dropped (closing an fd auto-deregisters it).
        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            // SAFETY: as in `ctl`; DEL ignores the event payload (the
            // non-null pointer keeps pre-2.6.9 kernel semantics happy).
            cvt(unsafe {
                epoll_ctl(self.epfd.as_raw_fd(), EPOLL_CTL_DEL, fd, &mut ev)
            })?;
            Ok(())
        }

        /// Block until readiness or `timeout` (`None` = forever),
        /// filling `events`. Returns the number of events delivered.
        pub fn wait(
            &self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<usize> {
            events.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
            // SAFETY: `buf` is a valid writable array of `buf.len()`
            // epoll_event slots for the duration of the call.
            let n = cvt(unsafe {
                epoll_wait(
                    self.epfd.as_raw_fd(),
                    buf.as_mut_ptr(),
                    buf.len() as c_int,
                    timeout_ms(timeout),
                )
            })? as usize;
            for ev in buf.iter().take(n) {
                // copy out of the (possibly packed) struct by value
                let bits = { ev.events };
                let token = { ev.data };
                events.push(Event {
                    token,
                    readable: bits & (EPOLLIN | EPOLLRDHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    broken: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(n)
        }
    }

    /// `struct sockaddr_in`, network byte order where the ABI says so.
    #[repr(C)]
    struct SockAddrV4 {
        family: u16,
        port: u16,
        addr: [u8; 4],
        zero: [u8; 8],
    }

    /// `struct sockaddr_in6`.
    #[repr(C)]
    struct SockAddrV6 {
        family: u16,
        port: u16,
        flowinfo: u32,
        addr: [u8; 16],
        scope_id: u32,
    }

    /// Begin a nonblocking TCP connect to `addr`. Returns the socket
    /// (already owned by a std `TcpStream`, already nonblocking) and
    /// whether the connect completed synchronously (loopback often
    /// does). When `false`, register the stream for writability and
    /// call [`connect_result`] once the poller reports it.
    pub fn start_connect(addr: &SocketAddr) -> io::Result<(TcpStream, bool)> {
        let domain = match addr {
            SocketAddr::V4(_) => AF_INET,
            SocketAddr::V6(_) => AF_INET6,
        };
        // SAFETY: plain syscall; the fd is wrapped immediately below.
        let fd = cvt(unsafe {
            socket(domain, SOCK_STREAM | SOCK_NONBLOCK | SOCK_CLOEXEC, 0)
        })?;
        // SAFETY: `fd` is a fresh, valid, owned stream socket.
        let stream = unsafe { TcpStream::from_raw_fd(fd) };
        let rc = match addr {
            SocketAddr::V4(v4) => {
                let sa = SockAddrV4 {
                    family: AF_INET as u16,
                    port: v4.port().to_be(),
                    addr: v4.ip().octets(),
                    zero: [0; 8],
                };
                // SAFETY: `sa` is a correctly laid out sockaddr_in and
                // outlives the call; the kernel copies it.
                unsafe {
                    connect(
                        fd,
                        (&sa as *const SockAddrV4).cast(),
                        std::mem::size_of::<SockAddrV4>() as u32,
                    )
                }
            }
            SocketAddr::V6(v6) => {
                let sa = SockAddrV6 {
                    family: AF_INET6 as u16,
                    port: v6.port().to_be(),
                    flowinfo: v6.flowinfo().to_be(),
                    addr: v6.ip().octets(),
                    scope_id: v6.scope_id(),
                };
                // SAFETY: as above, for sockaddr_in6.
                unsafe {
                    connect(
                        fd,
                        (&sa as *const SockAddrV6).cast(),
                        std::mem::size_of::<SockAddrV6>() as u32,
                    )
                }
            }
        };
        if rc == 0 {
            return Ok((stream, true));
        }
        let err = io::Error::last_os_error();
        if err.raw_os_error() == Some(EINPROGRESS) {
            Ok((stream, false))
        } else {
            Err(err)
        }
    }

    /// Read back the outcome of a nonblocking connect after the poller
    /// reported the socket writable: `Ok(())` = connected, `Err` = the
    /// pending socket error (e.g. `ECONNREFUSED`).
    pub fn connect_result(stream: &TcpStream) -> io::Result<()> {
        let mut err: c_int = 0;
        let mut len = std::mem::size_of::<c_int>() as u32;
        // SAFETY: `err`/`len` are valid for writes of the sizes passed;
        // SO_ERROR writes a c_int.
        cvt(unsafe {
            getsockopt(
                stream.as_raw_fd(),
                SOL_SOCKET,
                SO_ERROR,
                (&mut err as *mut c_int).cast(),
                &mut len,
            )
        })?;
        if err == 0 {
            Ok(())
        } else {
            Err(io::Error::from_raw_os_error(err))
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
pub use fallback::Poller;

#[cfg(all(unix, not(target_os = "linux")))]
mod fallback {
    //! Portable `poll(2)` readiness for non-Linux unixes (dev boxes;
    //! production and CI are Linux/epoll). The interest set lives in
    //! userspace and the pollfd array is rebuilt per wait — O(n) per
    //! tick, which is fine at fallback scale. Level-triggered only.

    use super::{timeout_ms, Event, Interest};
    use crate::sync::Mutex;
    use std::collections::HashMap;
    use std::io;
    use std::os::fd::RawFd;
    use std::os::raw::{c_int, c_short, c_uint};
    use std::time::Duration;

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;

    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    /// `poll(2)`-backed stand-in for the Linux epoll poller.
    #[derive(Debug, Default)]
    pub struct Poller {
        interests: Mutex<HashMap<RawFd, (u64, Interest)>>,
    }

    impl Poller {
        /// A fresh (empty) interest set.
        pub fn new() -> io::Result<Poller> {
            Ok(Poller::default())
        }

        /// Start watching `fd` under `token`.
        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.interests.lock().unwrap().insert(fd, (token, interest));
            Ok(())
        }

        /// Change what an already-registered `fd` wants to hear.
        pub fn reregister(
            &self,
            fd: RawFd,
            token: u64,
            interest: Interest,
        ) -> io::Result<()> {
            self.register(fd, token, interest)
        }

        /// Stop watching `fd`.
        pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
            self.interests.lock().unwrap().remove(&fd);
            Ok(())
        }

        /// Block until readiness or `timeout`, filling `events`.
        pub fn wait(
            &self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<usize> {
            events.clear();
            let mut fds: Vec<PollFd> = Vec::new();
            let mut tokens: Vec<u64> = Vec::new();
            for (&fd, &(token, interest)) in
                self.interests.lock().unwrap().iter()
            {
                let mut ev: c_short = 0;
                if interest.readable {
                    ev |= POLLIN;
                }
                if interest.writable {
                    ev |= POLLOUT;
                }
                fds.push(PollFd { fd, events: ev, revents: 0 });
                tokens.push(token);
            }
            // SAFETY: `fds` is a valid array of fds.len() pollfd slots
            // for the duration of the call.
            let rc = unsafe {
                poll(fds.as_mut_ptr(), fds.len() as c_uint, timeout_ms(timeout))
            };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            for (pfd, &token) in fds.iter().zip(&tokens) {
                if pfd.revents == 0 {
                    continue;
                }
                events.push(Event {
                    token,
                    readable: pfd.revents & POLLIN != 0,
                    writable: pfd.revents & POLLOUT != 0,
                    broken: pfd.revents & (POLLERR | POLLHUP) != 0,
                });
            }
            Ok(events.len())
        }
    }
}

#[cfg(not(unix))]
compile_error!(
    "the reactor serving core targets unix (epoll on Linux, poll(2) \
     elsewhere); no Windows backend is implemented"
);

/// Cross-thread wakeup for a blocked [`Poller::wait`]: a nonblocking
/// UDP socket connected to itself. Any thread holding a clone handle
/// calls [`Waker::wake`]; the loop registers the socket read-side and
/// [`Waker::drain`]s it when it fires. Built from pure std so the
/// unsafe surface stays confined to the poller above.
#[derive(Debug)]
pub struct Waker {
    sock: std::net::UdpSocket,
}

impl Waker {
    /// Bind a loopback self-connected datagram socket.
    pub fn new() -> io::Result<Waker> {
        let sock = std::net::UdpSocket::bind("127.0.0.1:0")?;
        sock.connect(sock.local_addr()?)?;
        sock.set_nonblocking(true)?;
        Ok(Waker { sock })
    }

    /// The fd to register (read interest) in the loop's poller.
    pub fn raw_fd(&self) -> std::os::fd::RawFd {
        use std::os::fd::AsRawFd;
        self.sock.as_raw_fd()
    }

    /// Nudge the loop. Never blocks; a full socket buffer means a wake
    /// is already pending, which is all a wake means.
    pub fn wake(&self) {
        let _ = self.sock.send(&[1]);
    }

    /// Swallow pending wake datagrams (loop side).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while self.sock.recv(&mut buf).is_ok() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::time::Instant;

    #[test]
    fn timeout_rounds_up_not_down() {
        assert_eq!(timeout_ms(None), -1);
        assert_eq!(timeout_ms(Some(Duration::ZERO)), 0);
        assert_eq!(timeout_ms(Some(Duration::from_micros(10))), 1);
        assert_eq!(timeout_ms(Some(Duration::from_millis(250))), 250);
    }

    #[test]
    fn waker_wakes_a_blocked_poll() {
        let poller = Poller::new().unwrap();
        let waker = Waker::new().unwrap();
        poller.register(waker.raw_fd(), 7, Interest::READ).unwrap();
        // no wake: the wait times out quietly
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty());
        // wake from another thread: the wait returns promptly
        let t = Instant::now();
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(10));
                waker.wake();
            });
            poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        });
        assert!(t.elapsed() < Duration::from_secs(5));
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        waker.drain();
    }

    #[test]
    fn poller_reports_readable_on_tcp_data() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.register(server.as_raw_fd(), 42, Interest::READ).unwrap();
        client.write_all(b"ping\n").unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        assert!(events.iter().any(|e| e.token == 42 && e.readable));
        let mut buf = [0u8; 8];
        let n = (&server).read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping\n");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn nonblocking_connect_completes_and_reports_refusal() {
        use super::linux::{connect_result, start_connect};
        // a live listener: the connect either completes synchronously
        // (loopback fast path) or after one writability event
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (stream, done) = start_connect(&addr).unwrap();
        if !done {
            let poller = Poller::new().unwrap();
            poller
                .register(stream.as_raw_fd(), 1, Interest::WRITE)
                .unwrap();
            let mut events = Vec::new();
            poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
            assert!(!events.is_empty());
        }
        connect_result(&stream).unwrap();
        drop(listener);

        // a dead port: the deferred error surfaces through SO_ERROR
        let dead = TcpListener::bind("127.0.0.1:0").unwrap();
        let dead_addr = dead.local_addr().unwrap();
        drop(dead);
        match start_connect(&dead_addr) {
            Err(_) => {} // synchronous refusal is fine too
            Ok((stream, done)) => {
                if !done {
                    let poller = Poller::new().unwrap();
                    poller
                        .register(
                            stream.as_raw_fd(),
                            1,
                            Interest::read_write(),
                        )
                        .unwrap();
                    let mut events = Vec::new();
                    poller
                        .wait(&mut events, Some(Duration::from_secs(10)))
                        .unwrap();
                }
                assert!(
                    connect_result(&stream).is_err(),
                    "connect to a closed port must fail"
                );
            }
        }
    }
}
