//! The neural-compute interface used by the request path, with two
//! implementations:
//!
//! * [`PjrtEngine`] — the real thing: AOT artifacts on the PJRT client.
//! * [`NativeEngine`] — a pure-Rust functional twin (same random-feature
//!   embedding construction, same masked-attention math) used by tests
//!   and benches that must run without `make artifacts`, and as the
//!   cross-check oracle for the integration tests.
//!
//! Both satisfy [`Engine`]; everything downstream (vector store,
//! generator, coordinator) is implementation-agnostic.

use std::sync::Mutex;

use crate::error::Result;
use crate::runtime::client::Runtime;

/// Fixed shapes shared by both engines (must match the artifact manifest).
#[derive(Clone, Copy, Debug)]
pub struct EngineShape {
    pub batch: usize,
    pub max_tokens: usize,
    pub embed_dim: usize,
    pub shard_docs: usize,
    pub max_facts: usize,
}

impl Default for EngineShape {
    fn default() -> Self {
        // mirrors python/compile/model.py
        EngineShape {
            batch: 8,
            max_tokens: 32,
            embed_dim: 64,
            shard_docs: 1024,
            max_facts: 64,
        }
    }
}

/// Batched neural compute on the request path.
pub trait Engine: Send + Sync {
    /// Shapes this engine was built with.
    fn shape(&self) -> EngineShape;

    /// `[batch, max_tokens]` ids -> `[batch, embed_dim]` unit embeddings.
    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// `[batch, D]` x `[shard_docs, D]` -> `[batch, shard_docs]` scores.
    fn score(&self, q: &[f32], docs: &[f32]) -> Result<Vec<f32>>;

    /// `[batch, D]`, `[batch, max_facts, D]`, `[batch]` lens ->
    /// `[batch, max_facts]` attention weights.
    fn rank(&self, q: &[f32], facts: &[f32], lens: &[i32]) -> Result<Vec<f32>>;

    /// Human-readable backend name.
    fn backend(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// PJRT-backed engine
// ---------------------------------------------------------------------

/// [`Engine`] over the AOT artifacts (the production path).
///
/// Holds a small pool of compiled runtimes: PJRT execute calls are made
/// behind per-runtime mutexes, so `pool_size > 1` lets coordinator
/// workers run neural stages concurrently instead of serializing on one
/// lock (§Perf in EXPERIMENTS.md: +~2× serving throughput at 4 workers).
pub struct PjrtEngine {
    runtimes: Vec<Mutex<Runtime>>,
    next: std::sync::atomic::AtomicUsize,
    shape: EngineShape,
}

impl PjrtEngine {
    /// Wrap a single loaded runtime.
    pub fn new(runtime: Runtime) -> Self {
        let m = runtime.manifest();
        let shape = EngineShape {
            batch: m.batch,
            max_tokens: m.max_tokens,
            embed_dim: m.embed_dim,
            shard_docs: m.shard_docs,
            max_facts: m.max_facts,
        };
        PjrtEngine {
            runtimes: vec![Mutex::new(runtime)],
            next: std::sync::atomic::AtomicUsize::new(0),
            shape,
        }
    }

    /// Load a pool of `n` runtimes from the artifact directory.
    pub fn with_pool(dir: impl AsRef<std::path::Path>, n: usize) -> Result<Self> {
        let n = n.max(1);
        let first = Runtime::load(&dir)?;
        let mut engine = Self::new(first);
        for _ in 1..n {
            engine.runtimes.push(Mutex::new(Runtime::load(&dir)?));
        }
        Ok(engine)
    }

    /// Number of pooled runtimes.
    pub fn pool_size(&self) -> usize {
        self.runtimes.len()
    }

    /// Round-robin a runtime, preferring an uncontended one.
    fn with_runtime<T>(&self, f: impl Fn(&Runtime) -> Result<T>) -> Result<T> {
        let n = self.runtimes.len();
        let start = self
            .next
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // first pass: try-lock to dodge contention
        for i in 0..n {
            if let Ok(rt) = self.runtimes[(start + i) % n].try_lock() {
                return f(&rt);
            }
        }
        // all busy: block on our round-robin slot
        let rt = self.runtimes[start % n].lock().unwrap();
        f(&rt)
    }
}

impl Engine for PjrtEngine {
    fn shape(&self) -> EngineShape {
        self.shape
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.with_runtime(|rt| rt.embed(tokens))
    }

    fn score(&self, q: &[f32], docs: &[f32]) -> Result<Vec<f32>> {
        self.with_runtime(|rt| rt.score(q, docs))
    }

    fn rank(&self, q: &[f32], facts: &[f32], lens: &[i32]) -> Result<Vec<f32>> {
        self.with_runtime(|rt| rt.rank(q, facts, lens))
    }

    fn backend(&self) -> &'static str {
        "pjrt-cpu"
    }
}

// ---------------------------------------------------------------------
// Native reference engine
// ---------------------------------------------------------------------

/// Pure-Rust functional twin of the L2 graphs: random-feature token
/// embedding (sin features), mean pool, layer norm, L2 normalize; dot
/// product scoring; masked softmax attention. Constants differ from the
/// Python model's (both are seeded random), so *embeddings* differ, but
/// retrieval semantics — cosine ≈ token overlap — are identical, which
/// is what the artifact-less tests rely on.
pub struct NativeEngine {
    shape: EngineShape,
    freq: Vec<f32>,
    phase: Vec<f32>,
}

impl NativeEngine {
    /// Build with the default shapes.
    pub fn new() -> Self {
        Self::with_shape(EngineShape::default())
    }

    /// Build with explicit shapes (tests use small ones).
    pub fn with_shape(shape: EngineShape) -> Self {
        let mut rng = crate::util::rng::Rng::new(2025);
        let freq = (0..shape.embed_dim)
            .map(|_| 0.05 + 1.95 * rng.f32())
            .collect();
        let phase = (0..shape.embed_dim)
            .map(|_| rng.f32() * std::f32::consts::TAU)
            .collect();
        NativeEngine { shape, freq, phase }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine for NativeEngine {
    fn shape(&self) -> EngineShape {
        self.shape
    }

    fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let s = self.shape;
        assert_eq!(tokens.len(), s.batch * s.max_tokens);
        let mut out = vec![0f32; s.batch * s.embed_dim];
        for b in 0..s.batch {
            let row = &tokens[b * s.max_tokens..(b + 1) * s.max_tokens];
            let emb = &mut out[b * s.embed_dim..(b + 1) * s.embed_dim];
            let mut count = 0f32;
            for &id in row.iter().filter(|&&id| id != 0) {
                count += 1.0;
                for d in 0..s.embed_dim {
                    emb[d] += (id as f32 * self.freq[d] + self.phase[d]).sin();
                }
            }
            let count = count.max(1.0);
            for v in emb.iter_mut() {
                *v /= count;
            }
            // layer norm (gamma=1, beta=0)
            let mean: f32 = emb.iter().sum::<f32>() / s.embed_dim as f32;
            let var: f32 = emb.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
                / s.embed_dim as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for v in emb.iter_mut() {
                *v = (*v - mean) * inv;
            }
            // l2 normalize
            let norm: f32 = emb.iter().map(|v| v * v).sum::<f32>();
            let norm = norm.sqrt().max(1e-12);
            for v in emb.iter_mut() {
                *v /= norm;
            }
        }
        Ok(out)
    }

    fn score(&self, q: &[f32], docs: &[f32]) -> Result<Vec<f32>> {
        let s = self.shape;
        assert_eq!(q.len(), s.batch * s.embed_dim);
        assert_eq!(docs.len(), s.shard_docs * s.embed_dim);
        let mut out = vec![0f32; s.batch * s.shard_docs];
        for b in 0..s.batch {
            let qv = &q[b * s.embed_dim..(b + 1) * s.embed_dim];
            for n in 0..s.shard_docs {
                let dv = &docs[n * s.embed_dim..(n + 1) * s.embed_dim];
                out[b * s.shard_docs + n] =
                    qv.iter().zip(dv).map(|(a, c)| a * c).sum();
            }
        }
        Ok(out)
    }

    fn rank(&self, q: &[f32], facts: &[f32], lens: &[i32]) -> Result<Vec<f32>> {
        let s = self.shape;
        assert_eq!(q.len(), s.batch * s.embed_dim);
        assert_eq!(facts.len(), s.batch * s.max_facts * s.embed_dim);
        assert_eq!(lens.len(), s.batch);
        let scale = 1.0 / (s.embed_dim as f32).sqrt();
        let mut out = vec![0f32; s.batch * s.max_facts];
        for b in 0..s.batch {
            let l = (lens[b].max(0) as usize).min(s.max_facts);
            if l == 0 {
                continue;
            }
            let qv = &q[b * s.embed_dim..(b + 1) * s.embed_dim];
            let mut logits = vec![0f32; l];
            for (i, logit) in logits.iter_mut().enumerate() {
                let base = (b * s.max_facts + i) * s.embed_dim;
                let fv = &facts[base..base + s.embed_dim];
                *logit = qv.iter().zip(fv).map(|(a, c)| a * c).sum::<f32>() * scale;
            }
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f32;
            for logit in logits.iter_mut() {
                *logit = (*logit - m).exp();
                denom += *logit;
            }
            for (i, logit) in logits.iter().enumerate() {
                out[b * s.max_facts + i] = logit / denom;
            }
        }
        Ok(out)
    }

    fn backend(&self) -> &'static str {
        "native-rust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::tokenizer::tokenize_padded;

    fn tok_batch(texts: &[&str], shape: EngineShape) -> Vec<i32> {
        let mut out = Vec::new();
        for i in 0..shape.batch {
            let t = texts.get(i).copied().unwrap_or("");
            out.extend(tokenize_padded(t, shape.max_tokens));
        }
        out
    }

    #[test]
    fn native_embeddings_unit_norm() {
        let e = NativeEngine::new();
        let s = e.shape();
        let toks = tok_batch(&["cardiology ward nine", "surgery"], s);
        let emb = e.embed(&toks).unwrap();
        for b in 0..2 {
            let row = &emb[b * s.embed_dim..(b + 1) * s.embed_dim];
            let n: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "norm {n}");
        }
    }

    #[test]
    fn native_similarity_tracks_overlap() {
        let e = NativeEngine::new();
        let s = e.shape();
        let toks = tok_batch(
            &[
                "cardiology intensive care unit",
                "cardiology intensive care ward",
                "logistics supply chain office",
            ],
            s,
        );
        let emb = e.embed(&toks).unwrap();
        let dot = |a: usize, b: usize| -> f32 {
            emb[a * s.embed_dim..(a + 1) * s.embed_dim]
                .iter()
                .zip(&emb[b * s.embed_dim..(b + 1) * s.embed_dim])
                .map(|(x, y)| x * y)
                .sum()
        };
        assert!(dot(0, 1) > dot(0, 2) + 0.15, "{} vs {}", dot(0, 1), dot(0, 2));
    }

    #[test]
    fn native_rank_masks_and_normalizes() {
        let e = NativeEngine::new();
        let s = e.shape();
        let q = vec![0.1f32; s.batch * s.embed_dim];
        let facts = vec![0.05f32; s.batch * s.max_facts * s.embed_dim];
        let mut lens = vec![0i32; s.batch];
        lens[0] = 3;
        lens[1] = 0;
        let w = e.rank(&q, &facts, &lens).unwrap();
        let row0: f32 = w[..s.max_facts].iter().sum();
        assert!((row0 - 1.0).abs() < 1e-5);
        assert!(w[3] == 0.0, "masked positions zero");
        assert!(w[s.max_facts..2 * s.max_facts].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn native_score_shapes() {
        let shape = EngineShape {
            batch: 2,
            max_tokens: 8,
            embed_dim: 4,
            shard_docs: 8,
            max_facts: 4,
        };
        let e = NativeEngine::with_shape(shape);
        let q = vec![1.0f32; 2 * 4];
        let docs = vec![0.5f32; 8 * 4];
        let sres = e.score(&q, &docs).unwrap();
        assert_eq!(sres.len(), 16);
        assert!((sres[0] - 2.0).abs() < 1e-6);
    }
}
