//! Property-based tests for the improved Cuckoo Filter: random operation
//! sequences checked against a HashMap reference model, plus structural
//! invariants (no false negatives, expansion preserves state, maintain
//! never loses entries).

use std::collections::HashMap;

use cft_rag::filter::cuckoo::{CuckooConfig, CuckooFilter};
use cft_rag::filter::fingerprint::entity_key;
use cft_rag::forest::EntityAddress;
use cft_rag::util::proptest::{forall, forall_simple, shrink_vec, Config};
use cft_rag::util::rng::Rng;

/// A random filter operation.
#[derive(Clone, Debug)]
enum Op {
    Insert(u16, u8),
    Delete(u16),
    Lookup(u16),
    PushAddr(u16),
    Maintain,
}

fn gen_ops(rng: &mut Rng, max_len: usize) -> Vec<Op> {
    let n = rng.range(1, max_len + 1);
    (0..n)
        .map(|_| {
            let id = rng.below(200) as u16;
            match rng.below(10) {
                0..=3 => Op::Insert(id, rng.below(6) as u8),
                4..=5 => Op::Delete(id),
                6..=7 => Op::Lookup(id),
                8 => Op::PushAddr(id),
                _ => Op::Maintain,
            }
        })
        .collect()
}

fn key_of(id: u16) -> u64 {
    entity_key(&format!("prop-entity-{id}"))
}

fn addrs_of(id: u16, n: u8) -> Vec<EntityAddress> {
    (0..n as u32)
        .map(|i| EntityAddress::new(id as u32, i))
        .collect()
}

/// Execute ops against the filter and a HashMap model; compare after
/// every step. Exact-match operations (insert/delete/push) must agree
/// perfectly; lookups may additionally hit on fingerprint collisions
/// (false positives), so the model only demands no false *negatives*.
///
/// Address lists returned by `lookup` are fingerprint-addressed, so a
/// colliding entity may *shadow* the queried one (paper §4.5.1) — the
/// returned list must then be exactly some live entity's list. A torn
/// or corrupted list matches nobody and still fails.
fn check_sequence(ops: &[Op]) -> Result<(), String> {
    let mut cf = CuckooFilter::new(CuckooConfig {
        initial_buckets: 8, // tiny: forces evictions + expansions
        ..CuckooConfig::default()
    });
    let mut model: HashMap<u16, Vec<EntityAddress>> = HashMap::new();

    for (step, op) in ops.iter().enumerate() {
        match op {
            Op::Insert(id, n) => {
                let a = addrs_of(*id, *n);
                let inserted = cf.insert(key_of(*id), &a);
                let expected = !model.contains_key(id);
                if inserted != expected {
                    return Err(format!(
                        "step {step}: insert({id}) returned {inserted}, model says {expected}"
                    ));
                }
                if inserted {
                    model.insert(*id, a);
                }
            }
            Op::Delete(id) => {
                let deleted = cf.delete(key_of(*id));
                let expected = model.remove(id).is_some();
                if deleted != expected {
                    return Err(format!(
                        "step {step}: delete({id}) returned {deleted}, model says {expected}"
                    ));
                }
            }
            Op::Lookup(id) => {
                let hit = cf.lookup(key_of(*id));
                match model.get(id) {
                    Some(addrs) => {
                        let Some(h) = hit else {
                            return Err(format!(
                                "step {step}: false negative for {id}"
                            ));
                        };
                        let got = cf.addresses(h);
                        if &got != addrs
                            && !model.values().any(|v| v == &got)
                        {
                            return Err(format!(
                                "step {step}: lookup({id}) corrupt addresses: {got:?} vs {addrs:?}"
                            ));
                        }
                    }
                    None => { /* false positives allowed */ }
                }
            }
            Op::PushAddr(id) => {
                let pushed =
                    cf.push_address(key_of(*id), EntityAddress::new(999, *id as u32));
                let expected = model.contains_key(id);
                if pushed != expected {
                    return Err(format!(
                        "step {step}: push({id}) returned {pushed}, model says {expected}"
                    ));
                }
                if pushed {
                    model
                        .get_mut(id)
                        .unwrap()
                        .push(EntityAddress::new(999, *id as u32));
                }
            }
            Op::Maintain => cf.maintain(),
        }
        if cf.len() != model.len() {
            return Err(format!(
                "step {step}: len {} != model {}",
                cf.len(),
                model.len()
            ));
        }
    }

    // Final sweep: every model entry retrievable; lists exact up to
    // consistent shadowing.
    for (id, addrs) in &model {
        match cf.lookup(key_of(*id)) {
            None => return Err(format!("final: false negative for {id}")),
            Some(h) => {
                let got = cf.addresses(h);
                if &got != addrs && !model.values().any(|v| v == &got) {
                    return Err(format!("final: {id} addresses {got:?} != {addrs:?}"));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn random_op_sequences_match_model() {
    forall(
        Config { cases: 150, ..Config::default() },
        |rng| gen_ops(rng, 400),
        |ops| check_sequence(ops),
        |ops| shrink_vec(ops),
    );
}

/// The churn model the expand()/delete() bugs hid from: interleaved
/// insert/delete/push/lookup on a *tiny* table so the run crosses
/// several expansions, checked against a HashMap oracle. Before the
/// fixes this failed two ways: (a) the migration-retry path of
/// `expand()` dropped the unmigrated suffix and the in-flight kick
/// victim (false negatives after ≥1 failed doubling), and (b) deletes
/// never reclaimed block lists, so the arena grew with every cycle.
#[test]
fn churn_model_across_expansions() {
    forall_simple(
        20,
        |rng| rng.next_u64(),
        |&seed| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 2, // 8 slots: every run expands repeatedly
                seed,
                ..CuckooConfig::default()
            });
            let mut model: HashMap<u64, Vec<EntityAddress>> = HashMap::new();
            let mut rng = Rng::new(seed ^ 0x00C4_A217);
            let mut next = 0u64;
            let mut live: Vec<u64> = Vec::new();
            for step in 0..5000 {
                if live.is_empty() || rng.chance(0.62) {
                    let id = next;
                    next += 1;
                    let addrs = addrs_of((id % 511) as u16, (id % 4) as u8 + 1);
                    if !cf.insert(key_of_u64(id), &addrs) {
                        return Err(format!("step {step}: fresh insert {id} rejected"));
                    }
                    model.insert(id, addrs);
                    live.push(id);
                } else if rng.chance(0.55) {
                    let id = live.swap_remove(rng.range(0, live.len()));
                    if !cf.delete(key_of_u64(id)) {
                        return Err(format!("step {step}: delete {id} missed"));
                    }
                    model.remove(&id);
                } else {
                    let id = live[rng.range(0, live.len())];
                    match cf.lookup(key_of_u64(id)) {
                        None => {
                            return Err(format!("step {step}: false negative {id}"))
                        }
                        Some(h) => {
                            let got = cf.addresses(h);
                            // exact, or a consistent shadow (§4.5.1)
                            if got != model[&id]
                                && !model.values().any(|v| v == &got)
                            {
                                return Err(format!(
                                    "step {step}: {id} addresses corrupted"
                                ));
                            }
                        }
                    }
                }
            }
            if cf.stats().expansions < 3 {
                return Err(format!(
                    "only {} expansions — churn not exercised",
                    cf.stats().expansions
                ));
            }
            // final sweep: every live entry retrievable, exact addresses
            // up to consistent shadowing
            for (id, addrs) in &model {
                match cf.lookup(key_of_u64(*id)) {
                    None => return Err(format!("final: false negative {id}")),
                    Some(h) => {
                        let got = cf.addresses(h);
                        if &got != addrs && !model.values().any(|v| v == &got) {
                            return Err(format!("final: {id} addresses wrong"));
                        }
                    }
                }
            }
            if cf.len() != model.len() {
                return Err(format!("len {} != model {}", cf.len(), model.len()));
            }
            Ok(())
        },
    );
}

/// A 10k insert/delete cycle with fresh keys every cycle must not grow
/// the arena: freed block lists are reused (delete reclaims chains).
#[test]
fn arena_bounded_under_10k_churn() {
    let mut cf = CuckooFilter::new(CuckooConfig {
        initial_buckets: 64,
        ..CuckooConfig::default()
    });
    let per_cycle = 100u64;
    let mut high_water = 0usize;
    for cycle in 0..100u64 {
        for i in 0..per_cycle {
            let k = key_of_u64(cycle * per_cycle + i);
            assert!(cf.insert(k, &addrs_of((i % 300) as u16, 5)), "insert");
        }
        if cycle == 0 {
            high_water = cf.arena().blocks_allocated();
        }
        for i in 0..per_cycle {
            let k = key_of_u64(cycle * per_cycle + i);
            assert!(cf.delete(k), "delete");
        }
    }
    assert_eq!(cf.len(), 0);
    assert_eq!(cf.arena().blocks_in_use(), 0, "all chains reclaimed");
    assert!(
        cf.arena().blocks_allocated() <= high_water,
        "arena leaked under churn: {} blocks after, {} at first cycle",
        cf.arena().blocks_allocated(),
        high_water
    );
}

fn key_of_u64(id: u64) -> u64 {
    entity_key(&format!("churn-{id}"))
}

/// Incremental expansion, checked at every step boundary: cross the load
/// threshold on a tiny table so doublings migrate in 1–4-bucket steps,
/// interleave inserts/deletes with explicit [`CuckooFilter::migrate_step`]
/// calls, and after **every** boundary require that no model entry is
/// lost (false negative), none is double-placed across the two table
/// generations (`occurrences == 1`), and address lists stay exact up to
/// consistent fingerprint shadowing (§4.5.1).
#[test]
fn incremental_migration_sound_at_every_step_boundary() {
    forall_simple(
        25,
        |rng| (rng.next_u64(), rng.range(1, 5)),
        |&(seed, step)| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 8, // 32 slots: expansions start immediately
                migration_step_buckets: step,
                seed,
                ..CuckooConfig::default()
            });
            let mut model: HashMap<u64, Vec<EntityAddress>> = HashMap::new();
            let mut live: Vec<u64> = Vec::new();
            let mut rng = Rng::new(seed ^ 0x0051_E901);
            let mut next_id = 0u64;
            for round in 0..30 {
                for _ in 0..rng.range(1, 25) {
                    if live.is_empty() || rng.chance(0.8) {
                        let id = next_id;
                        next_id += 1;
                        let a = addrs_of((id % 200) as u16, (id % 3) as u8 + 1);
                        if !cf.insert(key_of_u64(id), &a) {
                            return Err(format!("round {round}: insert {id} rejected"));
                        }
                        model.insert(id, a);
                        live.push(id);
                    } else {
                        let id = live.swap_remove(rng.range(0, live.len()));
                        if !cf.delete(key_of_u64(id)) {
                            return Err(format!("round {round}: delete {id} missed"));
                        }
                        model.remove(&id);
                    }
                }
                // an explicit bounded step — the boundary under test
                cf.migrate_step();
                // nothing lost, lists exact up to consistent shadowing
                for (id, a) in &model {
                    match cf.lookup(key_of_u64(*id)) {
                        None => {
                            return Err(format!(
                                "round {round}: false negative {id} at step \
                                 boundary (pending={})",
                                cf.migration_pending()
                            ))
                        }
                        Some(h) => {
                            let got = cf.addresses(h);
                            if &got != a && !model.values().any(|v| v == &got) {
                                return Err(format!(
                                    "round {round}: {id} addresses corrupted"
                                ));
                            }
                        }
                    }
                }
                // nothing double-placed across the two generations
                // (sampled: occurrences() scans both tables)
                for _ in 0..10.min(live.len()) {
                    let id = live[rng.range(0, live.len())];
                    let occ = cf.occurrences(key_of_u64(id));
                    if occ != 1 {
                        return Err(format!(
                            "round {round}: {id} placed {occ} times at step boundary"
                        ));
                    }
                }
                if cf.len() != model.len() {
                    return Err(format!(
                        "round {round}: len {} != model {}",
                        cf.len(),
                        model.len()
                    ));
                }
            }
            if cf.stats().expansions == 0 {
                return Err("no expansion exercised".into());
            }
            // drain whatever is still pending; the world must be intact
            while cf.migrate_step() {}
            for id in &live {
                if cf.lookup(key_of_u64(*id)).is_none() {
                    return Err(format!("final: false negative {id}"));
                }
                if cf.occurrences(key_of_u64(*id)) != 1 {
                    return Err(format!("final: {id} double-placed"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn mass_insert_never_false_negative() {
    forall_simple(
        30,
        |rng| {
            let n = rng.range(1, 4000);
            let seed = rng.next_u64();
            (n, seed)
        },
        |&(n, seed)| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 16,
                seed,
                ..CuckooConfig::default()
            });
            for i in 0..n {
                let k = entity_key(&format!("k{seed}-{i}"));
                if !cf.insert(k, &[]) {
                    return Err(format!("insert {i}/{n} failed"));
                }
            }
            for i in 0..n {
                let k = entity_key(&format!("k{seed}-{i}"));
                if !cf.contains(k) {
                    return Err(format!("false negative at {i}/{n}"));
                }
            }
            if cf.load_factor() > 1.0 {
                return Err("load factor > 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn maintain_preserves_membership_under_heat() {
    forall_simple(
        30,
        |rng| {
            let ids: Vec<u16> = (0..rng.range(2, 60)).map(|_| rng.below(500) as u16).collect();
            let hot: Vec<u16> = (0..rng.range(1, 20)).map(|_| rng.below(500) as u16).collect();
            (ids, hot)
        },
        |(ids, hot)| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 4,
                ..CuckooConfig::default()
            });
            let mut inserted = Vec::new();
            for &id in ids {
                if cf.insert(key_of(id), &addrs_of(id, 2)) {
                    inserted.push(id);
                }
            }
            for &h in hot {
                cf.lookup(key_of(h));
            }
            cf.maintain();
            for &id in &inserted {
                let Some(hit) = cf.lookup(key_of(id)) else {
                    return Err(format!("{id} lost after maintain"));
                };
                let got = cf.addresses(hit);
                // exact, or a consistent fingerprint shadow (§4.5.1)
                if got != addrs_of(id, 2)
                    && !inserted.iter().any(|&o| got == addrs_of(o, 2))
                {
                    return Err(format!("{id} addresses corrupted"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn expansion_scales_power_of_two() {
    forall_simple(
        20,
        |rng| rng.range(1, 5000),
        |&n| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 32,
                ..CuckooConfig::default()
            });
            for i in 0..n {
                cf.insert(entity_key(&format!("e{i}")), &[]);
            }
            if !cf.buckets().is_power_of_two() {
                return Err(format!("buckets {} not a power of two", cf.buckets()));
            }
            // load must respect the threshold after growth
            if n > 64 && cf.load_factor() > 0.95 {
                return Err(format!("load factor {} too high", cf.load_factor()));
            }
            Ok(())
        },
    );
}
