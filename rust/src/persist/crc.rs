//! CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`) — the
//! per-record and whole-snapshot checksum of the persistence layer.
//!
//! Dependency-free: the 256-entry table is built by a `const fn` at
//! compile time. CRC-32 detects **every** single-bit error and every
//! burst up to 32 bits, which is exactly the corruption class the
//! snapshot/op-log formats must refuse to load silently (torn sector
//! tails, flipped bits from a bad disk or a truncated copy).

/// Reflected CRC-32 lookup table for polynomial `0xEDB88320`.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Streaming CRC-32 state. [`crc32`] is the one-shot convenience.
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    /// Fresh state (all-ones preset, per the IEEE definition).
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb bytes.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            let idx = ((self.state ^ b as u32) & 0xFF) as usize;
            self.state = (self.state >> 8) ^ TABLE[idx];
        }
    }

    /// Final checksum (the state may keep absorbing afterwards; `finish`
    /// only applies the closing inversion).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_ieee_check_value() {
        // The canonical CRC-32 test vector ("check" in the Rocksoft
        // model): CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn detects_every_single_bit_flip() {
        let data: Vec<u8> = (0u8..=63).collect();
        let clean = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(
                    crc32(&flipped),
                    clean,
                    "flip at byte {byte} bit {bit} undetected"
                );
            }
        }
    }
}
