//! Quickstart: build a small entity forest, index it with the improved
//! Cuckoo Filter, retrieve an entity's addresses, and print its
//! hierarchical context — the paper's core loop in ~50 lines.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use cft_rag::forest::{builder::build_trees, Forest};
use cft_rag::retrieval::context::generate_context;
use cft_rag::retrieval::cuckoo_rag::CuckooTRag;
use cft_rag::retrieval::Retriever;

fn main() {
    // 1. Knowledge: (child, parent) relations from two organizations.
    let mut forest = Forest::new();
    build_trees(
        &mut forest,
        &[
            rel("cardiology", "mercy hospital"),
            rel("surgery", "mercy hospital"),
            rel("icu", "cardiology"),
            rel("recovery ward", "surgery"),
        ],
    );
    build_trees(
        &mut forest,
        &[
            rel("cardiology", "riverside clinic"),
            rel("day unit", "cardiology"),
        ],
    );
    let forest = Arc::new(forest);
    let stats = forest.stats();
    println!(
        "forest: {} trees, {} nodes, {} distinct entities",
        stats.trees, stats.nodes, stats.distinct_entities
    );

    // 2. Index with the paper's Cuckoo Filter (temperature + block lists).
    let mut retriever = CuckooTRag::new(forest.clone());

    // 3. One O(1) lookup returns every address across the forest.
    let addresses = retriever.find("cardiology");
    println!("\n'cardiology' occurs at {} addresses:", addresses.len());
    for a in &addresses {
        println!("  tree {} node {}", a.tree, a.node);
    }

    // 4. Algorithm 3: n-level hierarchical context.
    let context = generate_context(&forest, "cardiology", &addresses, 2);
    println!("\ncontext ({} facts):", context.len());
    print!("{}", context.render());

    // 5. Temperatures: repeated lookups promote the entity in its bucket.
    for _ in 0..5 {
        retriever.find("cardiology");
    }
    retriever.maintain();
    println!(
        "\ncardiology temperature: {:?} (bucket position {:?})",
        retriever
            .filter()
            .temperature(cft_rag::filter::entity_key("cardiology")),
        retriever
            .filter()
            .bucket_position(cft_rag::filter::entity_key("cardiology")),
    );
}

fn rel(c: &str, p: &str) -> (String, String) {
    (c.to_string(), p.to_string())
}
