//! The improved Cuckoo Filter — the paper's core contribution (§3).
//!
//! A partial-key cuckoo hash table (Fan et al. 2014) whose entries carry,
//! besides the fingerprint, the paper's two additions:
//!
//! * a **temperature** — access counter bumped on every hit; buckets are
//!   re-sorted by descending temperature during maintenance so linear
//!   in-bucket scans hit hot entities first (§3.1, ablated in Figure 5);
//! * the **head of a block linked list** of all forest addresses of the
//!   entity (§3.1), so one O(1) lookup replaces a whole forest BFS.
//!
//! Layout is struct-of-arrays: the hot fingerprint array is scanned on
//! lookup; temperatures, list heads and the (cold) original keys live in
//! parallel arrays touched only on hits, maintenance, and expansion.
//!
//! # Incremental expansion (the §1 "double expansion" path)
//!
//! Expansion doubles the bucket count and re-inserts every live entry
//! from its stored key — the paper's "original elements are re-hashed
//! and migrated". Since PR 2 that migration is **incremental**: crossing
//! the load threshold allocates the doubled table *aside* as a migration
//! target, and live entries move old-bucket-range by old-bucket-range in
//! steps of [`CuckooConfig::migration_step_buckets`] buckets. Between
//! steps the filter serves from **both generations** — an entry lives in
//! exactly one of them at any instant (a bucket range is drained and
//! re-placed within a single step, under the same exclusive borrow) —
//! so lookups stay exact mid-migration and no caller ever waits for a
//! whole-table rebuild: the longest exclusive hold is one step. Every
//! mutating operation (insert / delete / push_address) drives one step,
//! [`CuckooFilter::maintain`] drains to completion, and the sharded
//! wrapper ([`crate::filter::sharded`]) interleaves explicit
//! [`CuckooFilter::migrate_step`] calls with its readers. A migration
//! collision storm (vanishingly rare) discards only the partial target
//! and retries at double the size — the snapshot-and-replay guarantee of
//! the PR-1 fix is preserved per target generation, so no entry is ever
//! dropped or double-placed.
//!
//! **Concurrency:** temperatures and per-bucket dirty flags are atomics,
//! so [`CuckooFilter::lookup_shared`] works through `&self` — many
//! readers can probe in parallel under a shard *read* lock (see
//! `filter::sharded`), with temperature bumps as relaxed increments.
//! Every structural mutation (insert / delete / migration step) still
//! takes `&mut self` and therefore an exclusive lock, but since PR 2 the
//! exclusive holds are *bounded*: migration moves one bucket range per
//! step, and maintenance is split into a read-only planning pass
//! ([`CuckooFilter::plan_maintenance`]) and per-bucket validated swaps
//! ([`CuckooFilter::apply_bucket_plan`]).

use crate::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering::Relaxed};

use crate::filter::blocklist::{BlockArena, NIL};
use crate::filter::fingerprint::{alt_index, fingerprint, primary_index};
use crate::forest::EntityAddress;
use crate::util::rng::Rng;

/// Tunables (paper values as defaults).
#[derive(Clone, Copy, Debug)]
pub struct CuckooConfig {
    /// Initial bucket count (rounded up to a power of two). Paper: 1024.
    pub initial_buckets: usize,
    /// Slots per bucket. Paper: 4.
    pub slots: usize,
    /// Fingerprint width in bits. Paper: 12.
    pub fingerprint_bits: u32,
    /// Max displacement chain length before declaring the table full.
    pub max_kicks: usize,
    /// Expand when load factor would exceed this.
    pub load_threshold: f64,
    /// Adaptive temperature sorting (§3.1) — ablation switch.
    pub sort_by_temperature: bool,
    /// Old buckets migrated per incremental expansion step. `0` = the
    /// whole table in one step (the pre-PR-2 monolithic behavior, kept
    /// as the comparison arm of `benches/concurrent.rs`). Smaller steps
    /// bound reader stalls during growth more tightly at the cost of
    /// serving from two generations for longer.
    pub migration_step_buckets: usize,
    /// RNG seed for eviction victim choice.
    pub seed: u64,
}

impl Default for CuckooConfig {
    fn default() -> Self {
        CuckooConfig {
            initial_buckets: 1024,
            slots: 4,
            fingerprint_bits: 12,
            max_kicks: 500,
            load_threshold: 0.94,
            sort_by_temperature: true,
            migration_step_buckets: 64,
            seed: 0xCF17_4A06,
        }
    }
}

/// Number of kick-depth histogram buckets in [`CuckooStats`].
pub const KICK_DEPTH_BUCKETS: usize = 8;

/// Bucket index for one insert's displacement-chain depth. Ranges:
/// `0, 1, 2, 3–4, 5–8, 9–16, 17–64, 65+` — log-ish spacing so a
/// rising tail (the "table is getting full" signal) is visible long
/// before inserts start failing at `max_kicks`.
fn kick_depth_bucket(depth: u64) -> usize {
    match depth {
        0 => 0,
        1 => 1,
        2 => 2,
        3..=4 => 3,
        5..=8 => 4,
        9..=16 => 5,
        17..=64 => 6,
        _ => 7,
    }
}

/// Counters reported by benches, EXPERIMENTS.md and the serving
/// layer's filter telemetry (`\x01stats` / `docs/OBSERVABILITY.md`).
#[derive(Clone, Copy, Debug, Default)]
pub struct CuckooStats {
    pub inserts: u64,
    pub kicks: u64,
    pub expansions: u64,
    /// incremental migration steps driven (several per expansion)
    pub migration_steps: u64,
    pub lookups: u64,
    /// slots probed across all lookups (the metric temperature sorting improves)
    pub slots_probed: u64,
    /// Histogram of displacement-chain depth per placement (see
    /// [`KICK_DEPTH_BUCKETS`] for the bucket ranges). Every placement
    /// lands in exactly one bucket — depth 0 means the entry went
    /// straight into an empty slot.
    pub kick_depth_hist: [u64; KICK_DEPTH_BUCKETS],
}

impl CuckooStats {
    /// Sum counters (sharded-filter aggregation).
    pub fn merge(&mut self, other: CuckooStats) {
        self.inserts += other.inserts;
        self.kicks += other.kicks;
        self.expansions += other.expansions;
        self.migration_steps += other.migration_steps;
        self.lookups += other.lookups;
        self.slots_probed += other.slots_probed;
        for (a, b) in self.kick_depth_hist.iter_mut().zip(other.kick_depth_hist) {
            *a += b;
        }
    }

    /// Record one placement's displacement-chain depth.
    pub fn record_kick_depth(&mut self, depth: u64) {
        self.kick_depth_hist[kick_depth_bucket(depth)] += 1;
    }
}

/// A successful lookup: the entity's block-list head.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LookupHit {
    /// Head of the block linked list of addresses (NIL if entity was
    /// inserted with no addresses).
    pub head: u32,
}

/// An entry carried between table generations: (key, temperature, head).
type Entry = (u64, u32, u32);

/// The two candidate buckets of a key, deduplicated: when `i1 == i2`
/// (which partial-key hashing does produce), the bucket is yielded once
/// so no probe site scans — or counts — the same slots twice.
#[inline]
fn bucket_pair(i1: usize, i2: usize) -> impl Iterator<Item = usize> {
    std::iter::once(i1).chain((i2 != i1).then_some(i2))
}

/// SWAR scan of one 4-lane fingerprint word: returns the first slot
/// holding `fp` (if any before the first empty lane) and the number
/// of slots a linear scan would have probed — so temperature-sorting
/// statistics stay exact while the scan itself is branch-light.
///
/// Buckets are left-packed (inserts fill the first hole, deletes
/// compact), so lanes at/after the first empty lane are all zero.
#[inline]
fn scan4(word: u64, fp: u16) -> (Option<usize>, u64) {
    const LO: u64 = 0x0001_0001_0001_0001;
    const HI: u64 = 0x8000_8000_8000_8000;
    let pat = (fp as u64).wrapping_mul(LO); // broadcast fp to 4 lanes
    let x = word ^ pat; // zero lane <=> fingerprint match
    // first-zero-lane detection; the lowest flagged lane is exact
    let hit = x.wrapping_sub(LO) & !x & HI;
    let empty = word.wrapping_sub(LO) & !word & HI;
    let hit_pos = (hit.trailing_zeros() / 16) as usize; // 4 if none
    let empty_pos = (empty.trailing_zeros() / 16) as usize; // 4 if none
    if hit != 0 && hit_pos < empty_pos {
        (Some(hit_pos), hit_pos as u64 + 1)
    } else {
        // linear scan would probe up to and including the first
        // empty slot, or the whole bucket
        (None, (empty_pos + 1).min(4) as u64)
    }
}

/// The one slot-ordering policy within a bucket — occupied before empty
/// (empty slots always carry temperature 0), then hotter first —
/// expressed as an ascending sort key. Shared by the in-place insertion
/// sort (`Table::sort_bucket`, via `slot_less`) and the epoch-style
/// planner ([`CuckooFilter::plan_maintenance`]) so the two maintenance
/// paths can never drift apart.
#[inline]
fn slot_rank(fp: u16, temp: u32) -> (bool, std::cmp::Reverse<u32>) {
    (fp == 0, std::cmp::Reverse(temp))
}

/// One table generation: the bucket/slot arrays of a (possibly
/// in-migration) cuckoo table. The filter owns one primary `Table` plus,
/// while an expansion is in flight, a doubled migration target.
#[derive(Debug)]
struct Table {
    nbuckets: usize,
    slots: usize,
    /// hot path: fingerprints, 0 = empty slot; len = nbuckets * slots
    fps: Vec<u16>,
    /// temperature per slot (atomic: bumped by shared-borrow lookups)
    temps: Vec<AtomicU32>,
    /// block-list head per slot (NIL when none)
    heads: Vec<u32>,
    /// cold path: original keys, used for migration & exact-match checks
    keys: Vec<u64>,
    /// buckets whose temperature order may be stale
    dirty: Vec<AtomicBool>,
}

impl Clone for Table {
    fn clone(&self) -> Self {
        Table {
            nbuckets: self.nbuckets,
            slots: self.slots,
            fps: self.fps.clone(),
            temps: self
                .temps
                .iter()
                .map(|t| AtomicU32::new(t.load(Relaxed)))
                .collect(),
            heads: self.heads.clone(),
            keys: self.keys.clone(),
            dirty: self
                .dirty
                .iter()
                .map(|d| AtomicBool::new(d.load(Relaxed)))
                .collect(),
        }
    }
}

impl Table {
    fn new(nbuckets: usize, slots: usize) -> Self {
        let n = nbuckets * slots;
        Table {
            nbuckets,
            slots,
            fps: vec![0; n],
            temps: std::iter::repeat_with(|| AtomicU32::new(0))
                .take(n)
                .collect(),
            heads: vec![NIL; n],
            keys: vec![0; n],
            dirty: std::iter::repeat_with(|| AtomicBool::new(false))
                .take(nbuckets)
                .collect(),
        }
    }

    #[inline]
    fn capacity(&self) -> usize {
        self.nbuckets * self.slots
    }

    #[inline]
    fn slot_range(&self, bucket: usize) -> std::ops::Range<usize> {
        bucket * self.slots..(bucket + 1) * self.slots
    }

    /// Fingerprint and candidate buckets of `key` in *this* generation
    /// (the two generations differ in `nbuckets`, so indices differ too).
    #[inline]
    fn probe(&self, key: u64, fingerprint_bits: u32) -> (u16, usize, usize) {
        let fp = fingerprint(key, fingerprint_bits);
        let i1 = primary_index(key, self.nbuckets);
        let i2 = alt_index(i1, fp, self.nbuckets);
        (fp, i1, i2)
    }

    fn empty_slot(&self, bucket: usize) -> Option<usize> {
        self.slot_range(bucket).find(|&s| self.fps[s] == 0)
    }

    fn write_slot(&mut self, s: usize, fp: u16, key: u64, temp: u32, head: u32) {
        self.fps[s] = fp;
        self.keys[s] = key;
        *self.temps[s].get_mut() = temp;
        self.heads[s] = head;
        *self.dirty[s / self.slots].get_mut() = true;
    }

    fn clear_slot(&mut self, s: usize) {
        self.fps[s] = 0;
        self.keys[s] = 0;
        *self.temps[s].get_mut() = 0;
        self.heads[s] = NIL;
    }

    /// One 64-bit load of a 4-slot bucket's fingerprints (the default
    /// layout: 4 × u16 = one word), with [`scan4`]'s lane convention:
    /// slot `i` of the bucket occupies bits `16*i..16*i+16`.
    #[inline]
    fn bucket_word(&self, bucket: usize) -> u64 {
        debug_assert_eq!(self.slots, 4);
        let base = bucket * 4;
        debug_assert!(
            base + 4 <= self.fps.len(),
            "bucket {bucket} out of range for {} fingerprint slots",
            self.fps.len()
        );
        if cfg!(target_endian = "little") {
            // SAFETY:
            // * bounds: `fps` is a Vec<u16> of exactly `nbuckets * 4`
            //   elements (`slots == 4` is asserted above; every Table
            //   constructor sizes fps as nbuckets*slots), and `bucket <
            //   nbuckets` at every call site, so `base + 4 <= fps.len()`
            //   (debug-asserted above) and all 8 bytes read lie inside
            //   the allocation.
            // * alignment: `read_unaligned` has no alignment
            //   requirement; the pointer is only u16-aligned.
            // * validity: u64 has no invalid bit patterns and the
            //   source bytes are initialized Vec contents.
            // * lane order: on little-endian targets the in-memory
            //   order fps[base..base+4] lands in bits 0..16, 16..32,
            //   ... — exactly the lane convention `scan4` assumes.
            //   Big-endian targets take the safe fold below, which
            //   builds the identical word explicitly.
            unsafe {
                (self.fps.as_ptr().add(base) as *const u64).read_unaligned()
            }
        } else {
            let mut w = 0u64;
            for i in 0..4 {
                w |= u64::from(self.fps[base + i]) << (16 * i);
            }
            w
        }
    }

    #[inline]
    fn find_fp(&self, bucket: usize, fp: u16) -> Option<usize> {
        if self.slots == 4 {
            let (pos, _) = scan4(self.bucket_word(bucket), fp);
            return pos.map(|p| bucket * 4 + p);
        }
        for s in self.slot_range(bucket) {
            if self.fps[s] == fp {
                return Some(s);
            }
            if self.fps[s] == 0 {
                return None; // left-packed: rest of the bucket is empty
            }
        }
        None
    }

    /// Like `find_fp` but records how many slots were probed (the
    /// quantity temperature sorting minimizes). Buckets are kept
    /// left-packed (inserts fill the first empty slot, deletes compact),
    /// so the scan terminates at the first empty slot.
    #[inline]
    fn find_fp_counting(
        &self,
        bucket: usize,
        fp: u16,
        probed: &AtomicU64,
    ) -> Option<usize> {
        if self.slots == 4 {
            let (pos, n) = scan4(self.bucket_word(bucket), fp);
            probed.fetch_add(n, Relaxed);
            return pos.map(|p| bucket * 4 + p);
        }
        let base = bucket * self.slots;
        for off in 0..self.slots {
            probed.fetch_add(1, Relaxed);
            let cur = self.fps[base + off];
            if cur == fp {
                return Some(base + off);
            }
            if cur == 0 {
                return None; // left-packed: rest of the bucket is empty
            }
        }
        None
    }

    /// Slot index of the exact key in this generation, if present.
    fn find_exact(&self, key: u64, fingerprint_bits: u32) -> Option<usize> {
        let (fp, i1, i2) = self.probe(key, fingerprint_bits);
        for b in bucket_pair(i1, i2) {
            for s in self.slot_range(b) {
                if self.fps[s] == fp && self.keys[s] == key {
                    return Some(s);
                }
            }
        }
        None
    }

    /// Restore the left-packed invariant after clearing slot `hole`:
    /// shift the occupied suffix of the bucket one slot left (order of
    /// survivors — and thus temperature order — is preserved).
    fn compact_bucket(&mut self, bucket: usize, hole: usize) {
        let end = (bucket + 1) * self.slots;
        let mut dst = hole;
        for src in hole + 1..end {
            if self.fps[src] == 0 {
                break;
            }
            self.swap_slots(dst, src);
            dst += 1;
        }
    }

    #[inline]
    fn swap_slots(&mut self, a: usize, b: usize) {
        self.fps.swap(a, b);
        self.keys.swap(a, b);
        self.temps.swap(a, b);
        self.heads.swap(a, b);
    }

    /// Insertion-sort one bucket's slots: occupied before empty, higher
    /// temperature first. Buckets have ≤ 8 slots, so insertion sort wins.
    fn sort_bucket(&mut self, bucket: usize) {
        let base = bucket * self.slots;
        let n = self.slots;
        for i in 1..n {
            let mut j = i;
            while j > 0 && self.slot_less(base + j - 1, base + j) {
                self.swap_slots(base + j - 1, base + j);
                j -= 1;
            }
        }
    }

    /// True when slot `a` must sort after slot `b` (see [`slot_rank`]).
    #[inline]
    fn slot_less(&self, a: usize, b: usize) -> bool {
        slot_rank(self.fps[a], self.temps[a].load(Relaxed))
            > slot_rank(self.fps[b], self.temps[b].load(Relaxed))
    }

    /// Every live entry currently in this generation.
    fn collect_live(&self) -> Vec<Entry> {
        let mut live = Vec::new();
        for s in 0..self.fps.len() {
            if self.fps[s] != 0 {
                live.push((
                    self.keys[s],
                    self.temps[s].load(Relaxed),
                    self.heads[s],
                ));
            }
        }
        live
    }

    /// Place without expanding. On a failed kick chain the input entry is
    /// already in the table (the first write of the chain) and the final
    /// displaced victim is handed back as `Err` for the caller to re-home
    /// — nothing is silently dropped.
    fn try_place(
        &mut self,
        cfg: &CuckooConfig,
        rng: &mut Rng,
        stats: &mut CuckooStats,
        key: u64,
        temp: u32,
        head: u32,
    ) -> Result<(), Entry> {
        let fp = fingerprint(key, cfg.fingerprint_bits);
        let i1 = primary_index(key, self.nbuckets);
        let i2 = alt_index(i1, fp, self.nbuckets);
        for b in bucket_pair(i1, i2) {
            if let Some(s) = self.empty_slot(b) {
                self.write_slot(s, fp, key, temp, head);
                stats.record_kick_depth(0);
                return Ok(());
            }
        }
        let mut i = if rng.chance(0.5) { i1 } else { i2 };
        let mut cur = (fp, key, temp, head);
        let mut depth = 0u64;
        for _ in 0..cfg.max_kicks {
            // evict a random resident entry
            let s = i * self.slots + rng.range(0, self.slots);
            let victim = (
                self.fps[s],
                self.keys[s],
                self.temps[s].load(Relaxed),
                self.heads[s],
            );
            self.write_slot(s, cur.0, cur.1, cur.2, cur.3);
            cur = victim;
            stats.kicks += 1;
            depth += 1;

            i = alt_index(i, cur.0, self.nbuckets);
            if let Some(s2) = self.empty_slot(i) {
                self.write_slot(s2, cur.0, cur.1, cur.2, cur.3);
                stats.record_kick_depth(depth);
                return Ok(());
            }
        }
        stats.record_kick_depth(depth);
        Err((cur.1, cur.2, cur.3))
    }

    /// Approximate heap usage of this generation's arrays.
    fn memory_bytes(&self) -> usize {
        self.fps.capacity() * 2
            + self.temps.capacity() * 4
            + self.heads.capacity() * 4
            + self.keys.capacity() * 8
            + self.dirty.capacity()
    }
}

/// An in-flight doubling: the target generation plus the cursor into the
/// old (primary) table marking the first not-yet-drained bucket.
#[derive(Clone, Debug)]
struct Migration {
    target: Table,
    next_bucket: usize,
}

/// Which generation a key was found in (internal addressing for the
/// mutating exact-match paths while a migration is in flight).
enum Loc {
    Main(usize),
    Target(usize),
}

/// A planned, temperature-sorted rebuild of one bucket: computed under a
/// shared borrow ([`CuckooFilter::plan_maintenance`]), applied under a
/// brief exclusive borrow ([`CuckooFilter::apply_bucket_plan`]). The
/// `seen` snapshot doubles as a validation token — if the bucket changed
/// structurally between the two phases the plan is stale and rejected.
#[derive(Clone, Debug)]
pub struct BucketPlan {
    bucket: usize,
    /// (fp, key, head) per slot at plan time; temperatures are excluded
    /// on purpose — concurrent readers bump them, and a bump must not
    /// invalidate the plan.
    seen: Vec<(u16, u64, u32)>,
    /// Permutation to apply: new slot `j` receives old slot `order[j]`.
    order: Vec<usize>,
}

/// The improved Cuckoo Filter.
#[derive(Debug)]
pub struct CuckooFilter {
    cfg: CuckooConfig,
    /// Primary generation. While a migration is in flight this is the
    /// *old* table, progressively drained front-to-back.
    table: Table,
    /// In-flight doubling, if any. Boxed: inert (a fat pointer) on the
    /// common no-migration path.
    migration: Option<Box<Migration>>,
    arena: BlockArena,
    len: usize,
    rng: Rng,
    /// write-path counters (inserts / kicks / expansions / steps)
    stats: CuckooStats,
    /// read-path counters, atomic so `lookup_shared` can record them
    lookups: AtomicU64,
    slots_probed: AtomicU64,
}

impl Default for CuckooFilter {
    fn default() -> Self {
        Self::new(CuckooConfig::default())
    }
}

impl Clone for CuckooFilter {
    fn clone(&self) -> Self {
        CuckooFilter {
            cfg: self.cfg,
            table: self.table.clone(),
            migration: self.migration.clone(),
            arena: self.arena.clone(),
            len: self.len,
            rng: self.rng.clone(),
            stats: self.stats,
            lookups: AtomicU64::new(self.lookups.load(Relaxed)),
            slots_probed: AtomicU64::new(self.slots_probed.load(Relaxed)),
        }
    }
}

impl CuckooFilter {
    /// New filter with the given configuration.
    pub fn new(cfg: CuckooConfig) -> Self {
        let nbuckets = cfg.initial_buckets.next_power_of_two().max(1);
        CuckooFilter {
            table: Table::new(nbuckets, cfg.slots),
            migration: None,
            arena: BlockArena::new(),
            len: 0,
            rng: Rng::new(cfg.seed),
            stats: CuckooStats::default(),
            lookups: AtomicU64::new(0),
            slots_probed: AtomicU64::new(0),
            cfg,
        }
    }

    /// Entries stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bucket count of the primary table. An in-flight doubling's target
    /// is reported here only once its migration completes.
    pub fn buckets(&self) -> usize {
        self.table.nbuckets
    }

    /// Slots per bucket (configuration).
    pub fn slots_per_bucket(&self) -> usize {
        self.cfg.slots
    }

    /// Slots in the generation entries are being placed into — the
    /// doubled target while a migration is in flight, the primary table
    /// otherwise. This is the denominator of [`load_factor`].
    ///
    /// [`load_factor`]: CuckooFilter::load_factor
    pub fn capacity_slots(&self) -> usize {
        match &self.migration {
            Some(m) => m.target.capacity(),
            None => self.table.capacity(),
        }
    }

    /// Load factor: occupied slots / capacity slots.
    pub fn load_factor(&self) -> f64 {
        self.len as f64 / self.capacity_slots() as f64
    }

    /// Estimated false-positive rate at the current load: the classic
    /// cuckoo-filter bound `1 - (1 - 2^-f)^(2bα)` for fingerprint
    /// width `f`, bucket size `b` and load factor `α` — a lookup of an
    /// absent key compares against about `2bα` stored fingerprints.
    /// Monitoring-grade (the real rate also depends on key mixing);
    /// a drift upward means the table grew fuller or a migration is
    /// holding entries in two generations.
    pub fn estimated_fp_rate(&self) -> f64 {
        let per_cmp = 1.0 / f64::from(1u32 << self.cfg.fingerprint_bits.min(31));
        let cmps = 2.0 * self.cfg.slots as f64 * self.load_factor();
        1.0 - (1.0 - per_cmp).powf(cmps)
    }

    /// Counters (snapshot; read-path counters are atomics).
    pub fn stats(&self) -> CuckooStats {
        let mut s = self.stats;
        s.lookups = self.lookups.load(Relaxed);
        s.slots_probed = self.slots_probed.load(Relaxed);
        s
    }

    /// The block arena (for reading address lists from a [`LookupHit`]).
    pub fn arena(&self) -> &BlockArena {
        &self.arena
    }

    /// Approximate heap usage in bytes (both generations + arena).
    pub fn memory_bytes(&self) -> usize {
        self.table.memory_bytes()
            + self
                .migration
                .as_ref()
                .map_or(0, |m| m.target.memory_bytes())
            + self.arena.memory_bytes()
    }

    /// Like [`memory_bytes`](CuckooFilter::memory_bytes), but counting
    /// only arena blocks backing **live** address lists — deletes (and
    /// the rebalancer's disowned-key drop pass) shrink this even though
    /// the arena retains freed capacity for reuse.
    pub fn live_memory_bytes(&self) -> usize {
        self.table.memory_bytes()
            + self
                .migration
                .as_ref()
                .map_or(0, |m| m.target.memory_bytes())
            + self.arena.live_bytes()
    }

    /// Bytes on the lookup-critical path only (fingerprint arrays).
    pub fn hot_bytes(&self) -> usize {
        self.table.fps.capacity() * 2
            + self
                .migration
                .as_ref()
                .map_or(0, |m| m.target.fps.capacity() * 2)
    }

    // ---------------------------------------------------------------
    // Insertion (paper Algorithm 1)
    // ---------------------------------------------------------------

    /// Insert an entity (by key) with all its forest addresses.
    ///
    /// Duplicate keys are rejected (`false`); use [`push_address`] to grow
    /// an existing entry. Crossing the load threshold starts an
    /// *incremental* doubling migration (see the module docs); insertion
    /// of a fresh key always succeeds, and every insert also drives one
    /// bounded migration step so growth amortizes across the write load.
    ///
    /// [`push_address`]: CuckooFilter::push_address
    pub fn insert(&mut self, key: u64, addrs: &[EntityAddress]) -> bool {
        // Exact duplicate check on the cold keys — a fingerprint-only
        // check would misreject fresh keys on fingerprint collisions.
        // Rejected duplicates still drive a step, keeping the "every
        // mutating call advances a pending migration" contract.
        if self.contains_exact(key) {
            self.migrate_buckets(self.step_buckets());
            return false;
        }
        if self.load_factor_after_insert() > self.cfg.load_threshold {
            if self.migration.is_some() {
                // Inserts outran the incremental steps (possible only
                // when the write burst exceeds step_size × old buckets):
                // finish this doubling before starting the next.
                self.migrate_buckets(usize::MAX);
            }
            self.start_migration();
        }
        let head = self.arena.build(addrs);
        self.place(key, 0, head);
        self.len += 1;
        self.stats.inserts += 1;
        self.migrate_buckets(self.step_buckets());
        true
    }

    fn load_factor_after_insert(&self) -> f64 {
        (self.len + 1) as f64 / self.capacity_slots() as f64
    }

    /// Place an entry into the active generation (the migration target
    /// while one is in flight), growing until it fits. A failed kick
    /// chain leaves the new entry placed and one displaced *victim*
    /// homeless (`Table::try_place` hands it back); the victim — never
    /// the table — is what gets re-placed after the growth, so no entry
    /// is ever dropped and no key is ever placed twice.
    fn place(&mut self, key: u64, temp: u32, head: u32) {
        let mut cur = (key, temp, head);
        loop {
            let res = match &mut self.migration {
                Some(m) => m.target.try_place(
                    &self.cfg,
                    &mut self.rng,
                    &mut self.stats,
                    cur.0,
                    cur.1,
                    cur.2,
                ),
                None => self.table.try_place(
                    &self.cfg,
                    &mut self.rng,
                    &mut self.stats,
                    cur.0,
                    cur.1,
                    cur.2,
                ),
            };
            match res {
                Ok(()) => return,
                Err(victim) => {
                    cur = victim;
                    if self.migration.is_some() {
                        self.grow_target();
                    } else {
                        self.start_migration();
                    }
                }
            }
        }
    }

    // ---------------------------------------------------------------
    // Lookup + context entry point (paper §3.4)
    // ---------------------------------------------------------------

    /// Membership probe by fingerprint only — the classic cuckoo-filter
    /// query, subject to fingerprint false positives. Checks both
    /// generations while a migration is in flight.
    pub fn contains(&self, key: u64) -> bool {
        let in_table = |t: &Table| {
            let (fp, i1, i2) = t.probe(key, self.cfg.fingerprint_bits);
            bucket_pair(i1, i2).any(|b| t.find_fp(b, fp).is_some())
        };
        if let Some(m) = &self.migration {
            if in_table(&m.target) {
                return true;
            }
        }
        in_table(&self.table)
    }

    /// Exact membership: fingerprint match confirmed against the stored
    /// key (cold path; used by insert's duplicate check and tests).
    pub fn contains_exact(&self, key: u64) -> bool {
        self.find_exact_loc(key).is_some()
    }

    /// Location of the exact key across both generations, if present.
    /// An entry lives in exactly one generation at any instant (a
    /// migration step drains and re-places atomically under `&mut`).
    fn find_exact_loc(&self, key: u64) -> Option<Loc> {
        if let Some(m) = &self.migration {
            if let Some(s) = m.target.find_exact(key, self.cfg.fingerprint_bits)
            {
                return Some(Loc::Target(s));
            }
        }
        self.table
            .find_exact(key, self.cfg.fingerprint_bits)
            .map(Loc::Main)
    }

    /// Lookup: on a fingerprint hit, bump the entity's temperature and
    /// return its block-list head (paper §3.4). Probes at most two
    /// buckets per generation; within a bucket the scan is linear, which
    /// is what the temperature ordering accelerates.
    pub fn lookup(&mut self, key: u64) -> Option<LookupHit> {
        self.lookup_shared(key)
    }

    /// [`lookup`](CuckooFilter::lookup) through a shared borrow — the
    /// concurrent read path. The structure is not mutated: the
    /// temperature bump is a relaxed atomic increment and the bucket's
    /// dirty flag a relaxed store, so any number of threads may call this
    /// concurrently (each under a shard read lock when sharded). While a
    /// migration is in flight the target generation is probed first,
    /// then the un-drained remainder of the old table — a reader never
    /// waits on migration progress.
    pub fn lookup_shared(&self, key: u64) -> Option<LookupHit> {
        self.lookups.fetch_add(1, Relaxed);
        if let Some(m) = &self.migration {
            if let Some(hit) = self.lookup_in(&m.target, key) {
                return Some(hit);
            }
        }
        self.lookup_in(&self.table, key)
    }

    fn lookup_in(&self, t: &Table, key: u64) -> Option<LookupHit> {
        let (fp, i1, i2) = t.probe(key, self.cfg.fingerprint_bits);
        for b in bucket_pair(i1, i2) {
            if let Some(s) = t.find_fp_counting(b, fp, &self.slots_probed) {
                // saturating atomic bump: never wraps hot counters to 0
                let _ =
                    t.temps[s].fetch_update(Relaxed, Relaxed, |x| x.checked_add(1));
                t.dirty[b].store(true, Relaxed);
                return Some(LookupHit { head: t.heads[s] });
            }
        }
        None
    }

    /// All addresses for a hit (collects the block list).
    pub fn addresses(&self, hit: LookupHit) -> Vec<EntityAddress> {
        self.arena.iter(hit.head).collect()
    }

    /// Iterate a hit's addresses without allocating.
    pub fn addresses_iter(
        &self,
        hit: LookupHit,
    ) -> impl Iterator<Item = EntityAddress> + '_ {
        self.arena.iter(hit.head)
    }

    // ---------------------------------------------------------------
    // Deletion (paper Algorithm 2)
    // ---------------------------------------------------------------

    /// Remove an entity by key. Exact (keys compared on the cold path to
    /// avoid deleting a fingerprint-colliding neighbour), in whichever
    /// generation currently holds the entry. The entity's block list is
    /// returned to the arena free list, so insert/delete churn does not
    /// grow the arena. Also drives one bounded migration step. Returns
    /// whether an entry was removed.
    pub fn delete(&mut self, key: u64) -> bool {
        let Some(loc) = self.find_exact_loc(key) else {
            return false;
        };
        let (t, s): (&mut Table, usize) = match loc {
            Loc::Main(s) => (&mut self.table, s),
            Loc::Target(s) => {
                (&mut self.migration.as_mut().expect("migration").target, s)
            }
        };
        let b = s / t.slots;
        let head = t.heads[s];
        t.clear_slot(s);
        t.compact_bucket(b, s);
        *t.dirty[b].get_mut() = true;
        self.arena.free_chain(head);
        self.len -= 1;
        self.migrate_buckets(self.step_buckets());
        true
    }

    /// Append a new forest address to an existing entity (dynamic update
    /// path: a new tree mentions a known entity). Exact-match on key;
    /// also drives one bounded migration step.
    pub fn push_address(&mut self, key: u64, addr: EntityAddress) -> bool {
        let Some(loc) = self.find_exact_loc(key) else {
            return false;
        };
        match loc {
            Loc::Main(s) => {
                self.table.heads[s] = self.arena.push(self.table.heads[s], addr);
            }
            Loc::Target(s) => {
                let m = self.migration.as_mut().expect("migration");
                m.target.heads[s] = self.arena.push(m.target.heads[s], addr);
            }
        }
        self.migrate_buckets(self.step_buckets());
        true
    }

    // ---------------------------------------------------------------
    // Incremental expansion (paper §1 "double expansion", PR-2 stepwise)
    // ---------------------------------------------------------------

    /// True while a doubling migration is in flight.
    pub fn migration_pending(&self) -> bool {
        self.migration.is_some()
    }

    /// Drive a pending migration forward by one bounded step (up to
    /// [`CuckooConfig::migration_step_buckets`] old buckets; `0` = all of
    /// them). Returns `true` while a migration remains pending. The
    /// sharded wrapper calls this between reader turns so no reader ever
    /// waits behind more than one step.
    pub fn migrate_step(&mut self) -> bool {
        crate::sync::hint::preemption_point();
        self.migrate_buckets(self.step_buckets())
    }

    #[inline]
    fn step_buckets(&self) -> usize {
        if self.cfg.migration_step_buckets == 0 {
            usize::MAX
        } else {
            self.cfg.migration_step_buckets
        }
    }

    /// Begin a doubling: allocate the target generation aside. Entries
    /// move later, in bounded steps.
    fn start_migration(&mut self) {
        debug_assert!(self.migration.is_none(), "doubling already in flight");
        self.stats.expansions += 1;
        self.migration = Some(Box::new(Migration {
            target: Table::new(self.table.nbuckets * 2, self.cfg.slots),
            next_bucket: 0,
        }));
    }

    /// Drain up to `max` not-yet-migrated old buckets into the target,
    /// re-hashing each live entry from its stored key (paper §1:
    /// "original elements are re-hashed and migrated"). Temperatures and
    /// block-list heads move with their entries; the arena is shared and
    /// untouched. Each bucket is drained and re-placed within this one
    /// exclusive borrow, so an entry is in exactly one generation at
    /// every observable instant. Returns `true` while the migration
    /// remains pending afterwards.
    fn migrate_buckets(&mut self, max: usize) -> bool {
        let Some(m) = self.migration.as_ref() else {
            return false;
        };
        let total = self.table.nbuckets;
        let start = m.next_bucket;
        let end = start.saturating_add(max.max(1)).min(total);
        self.stats.migration_steps += 1;
        let mut moved: Vec<Entry> = Vec::new();
        for s in start * self.table.slots..end * self.table.slots {
            if self.table.fps[s] != 0 {
                moved.push((
                    self.table.keys[s],
                    self.table.temps[s].load(Relaxed),
                    self.table.heads[s],
                ));
                self.table.clear_slot(s);
            }
        }
        for e in moved {
            self.place_in_target(e);
        }
        let m = self.migration.as_mut().expect("migration");
        m.next_bucket = end;
        if end == total {
            let done = *self.migration.take().expect("migration");
            self.table = done.target;
            return false;
        }
        true
    }

    /// Re-home one drained entry into the migration target, growing the
    /// target on a (vanishingly rare) kick storm.
    fn place_in_target(&mut self, mut cur: Entry) {
        loop {
            let m = self.migration.as_mut().expect("migration");
            match m.target.try_place(
                &self.cfg,
                &mut self.rng,
                &mut self.stats,
                cur.0,
                cur.1,
                cur.2,
            ) {
                Ok(()) => return,
                Err(victim) => {
                    cur = victim;
                    self.grow_target();
                }
            }
        }
    }

    /// Replace the migration target with one twice its size, replaying
    /// the target's live set (snapshotted once, up front) into the fresh
    /// table — the PR-1 snapshot-and-replay guarantee, per generation: a
    /// collision storm discards only the partial target, never an entry.
    /// The old table and its drain cursor are untouched.
    fn grow_target(&mut self) {
        let (live, mut nbuckets) = {
            let t = &self.migration.as_ref().expect("migration").target;
            (t.collect_live(), t.nbuckets * 2)
        };
        loop {
            self.stats.expansions += 1;
            let mut fresh = Table::new(nbuckets, self.cfg.slots);
            let mut ok = true;
            for &(key, temp, head) in &live {
                if fresh
                    .try_place(
                        &self.cfg,
                        &mut self.rng,
                        &mut self.stats,
                        key,
                        temp,
                        head,
                    )
                    .is_err()
                {
                    ok = false;
                    break;
                }
            }
            if ok {
                self.migration.as_mut().expect("migration").target = fresh;
                return;
            }
            nbuckets *= 2;
        }
    }

    // ---------------------------------------------------------------
    // Maintenance: adaptive temperature sorting (§3.1), epoch-style
    // ---------------------------------------------------------------

    /// Re-sort dirty buckets by descending temperature ("for each bucket,
    /// if it is free, sort" — run between query rounds, exactly how the
    /// paper's experiment uses idle time), first draining any pending
    /// migration. This is the monolithic single-owner path; concurrent
    /// callers should prefer the bounded-hold pair
    /// [`plan_maintenance`](CuckooFilter::plan_maintenance) /
    /// [`apply_bucket_plan`](CuckooFilter::apply_bucket_plan), which is
    /// what [`crate::filter::sharded::ShardedCuckooFilter::maintain`]
    /// uses. Sorting is a no-op when the ablation switch
    /// `sort_by_temperature` is off (migration still drains).
    pub fn maintain(&mut self) {
        self.migrate_buckets(usize::MAX);
        if !self.cfg.sort_by_temperature {
            return;
        }
        for b in 0..self.table.nbuckets {
            if *self.table.dirty[b].get_mut() {
                self.table.sort_bucket(b);
                *self.table.dirty[b].get_mut() = false;
            }
        }
    }

    /// Epoch-style maintenance, read phase: for every dirty bucket of the
    /// primary table, snapshot its content and compute the
    /// temperature-sorted slot order — entirely through `&self`, so it
    /// runs under a shard *read* lock with lookups proceeding in
    /// parallel. Returns no plans while a migration is in flight
    /// (migration steps take priority; buckets stay dirty and are planned
    /// on the next round) or when sorting is ablated off.
    pub fn plan_maintenance(&self) -> Vec<BucketPlan> {
        crate::sync::hint::preemption_point();
        if !self.cfg.sort_by_temperature || self.migration.is_some() {
            return Vec::new();
        }
        let t = &self.table;
        let mut plans = Vec::new();
        for b in 0..t.nbuckets {
            if !t.dirty[b].load(Relaxed) {
                continue;
            }
            let seen: Vec<(u16, u64, u32)> = t
                .slot_range(b)
                .map(|s| (t.fps[s], t.keys[s], t.heads[s]))
                .collect();
            let temps: Vec<u32> =
                t.slot_range(b).map(|s| t.temps[s].load(Relaxed)).collect();
            let mut order: Vec<usize> = (0..seen.len()).collect();
            // stable ascending sort on the shared key = occupied first,
            // hotter first, plan-time order on ties
            order.sort_by_key(|&i| slot_rank(seen[i].0, temps[i]));
            plans.push(BucketPlan { bucket: b, seen, order });
        }
        plans
    }

    /// Epoch-style maintenance, write phase: swap one planned bucket in.
    /// Validates that the bucket still matches the plan's structural
    /// snapshot (fingerprints, keys, heads — temperatures are allowed to
    /// have drifted and are carried over at their *current* values); a
    /// bucket mutated since planning is left untouched **and dirty**, so
    /// the next round re-plans it. Returns whether the swap was applied.
    pub fn apply_bucket_plan(&mut self, plan: &BucketPlan) -> bool {
        crate::sync::hint::preemption_point();
        if self.migration.is_some() {
            return false; // table generations changed; plan is stale
        }
        let t = &mut self.table;
        if plan.bucket >= t.nbuckets
            || plan.seen.len() != t.slots
            || plan.order.len() != t.slots
        {
            return false;
        }
        let base = plan.bucket * t.slots;
        for (off, &(fp, key, head)) in plan.seen.iter().enumerate() {
            if t.fps[base + off] != fp
                || t.keys[base + off] != key
                || t.heads[base + off] != head
            {
                return false; // stale: bucket mutated since the plan
            }
        }
        let temps: Vec<u32> = (0..t.slots)
            .map(|off| t.temps[base + off].load(Relaxed))
            .collect();
        for (j, &o) in plan.order.iter().enumerate() {
            let (fp, key, head) = plan.seen[o];
            t.fps[base + j] = fp;
            t.keys[base + j] = key;
            t.heads[base + j] = head;
            *t.temps[base + j].get_mut() = temps[o];
        }
        *t.dirty[plan.bucket].get_mut() = false;
        true
    }

    // ---------------------------------------------------------------
    // Test / bench helpers
    // ---------------------------------------------------------------

    /// Temperature of a key (exact match), if present. Test/bench helper.
    pub fn temperature(&self, key: u64) -> Option<u32> {
        self.find_exact_loc(key).map(|loc| {
            let (t, s) = match loc {
                Loc::Main(s) => (&self.table, s),
                Loc::Target(s) => {
                    (&self.migration.as_ref().expect("migration").target, s)
                }
            };
            t.temps[s].load(Relaxed)
        })
    }

    /// Position (0-based) of the key's slot within its bucket — lower is
    /// cheaper to find. Exposes the effect of temperature sorting.
    pub fn bucket_position(&self, key: u64) -> Option<usize> {
        self.find_exact_loc(key).map(|loc| match loc {
            Loc::Main(s) | Loc::Target(s) => s % self.cfg.slots,
        })
    }

    /// Number of slots, across both generations, whose stored key is
    /// exactly `key` — 1 for any present entity. The migration proptests
    /// use this to prove a step boundary never double-places an entry.
    pub fn occurrences(&self, key: u64) -> usize {
        let count = |t: &Table| {
            t.fps
                .iter()
                .zip(&t.keys)
                .filter(|&(&fp, &k)| fp != 0 && k == key)
                .count()
        };
        count(&self.table)
            + self.migration.as_ref().map_or(0, |m| count(&m.target))
    }

    // ---------------------------------------------------------------
    // Persistence (snapshot export / restore)
    // ---------------------------------------------------------------

    /// Export every live entry as `(key, temperature, addresses)` — the
    /// exact state a snapshot must capture. Iterates the migration
    /// target first (entries mid-doubling live there), then the main
    /// table; each present entry appears exactly once because a
    /// migration step removes from one generation as it places in the
    /// other.
    pub fn export_entries(&self) -> Vec<(u64, u32, Vec<EntityAddress>)> {
        let mut out = Vec::with_capacity(self.len);
        let mut collect = |t: &Table| {
            for s in 0..t.fps.len() {
                if t.fps[s] != 0 {
                    out.push((
                        t.keys[s],
                        t.temps[s].load(Relaxed),
                        self.arena.iter(t.heads[s]).collect(),
                    ));
                }
            }
        };
        if let Some(m) = &self.migration {
            collect(&m.target);
        }
        collect(&self.table);
        out
    }

    /// Drop every entry and any in-flight migration, returning the
    /// filter to its freshly-constructed geometry. Restore path: a
    /// loaded snapshot is authoritative, so the forest-built index is
    /// cleared before its entries are re-placed.
    pub fn clear(&mut self) {
        let nbuckets = self.cfg.initial_buckets.next_power_of_two().max(1);
        self.table = Table::new(nbuckets, self.cfg.slots);
        self.migration = None;
        self.arena = BlockArena::new();
        self.len = 0;
    }

    /// Overwrite the stored temperature of an exact-matched key.
    /// Restore path only: recovers snapshot temperatures without
    /// replaying the lookups that earned them.
    pub fn set_temperature(&mut self, key: u64, temp: u32) -> bool {
        let Some(loc) = self.find_exact_loc(key) else {
            return false;
        };
        let (t, s): (&mut Table, usize) = match loc {
            Loc::Main(s) => (&mut self.table, s),
            Loc::Target(s) => {
                (&mut self.migration.as_mut().expect("migration").target, s)
            }
        };
        *t.temps[s].get_mut() = temp;
        *t.dirty[s / t.slots].get_mut() = true;
        true
    }

    /// Re-place one snapshot entry: key + full address list + recorded
    /// temperature. Replaces any existing entry for the key (restore is
    /// idempotent). Returns whether the entry is present afterwards —
    /// `false` only if placement failed outright.
    pub fn restore_entry(
        &mut self,
        key: u64,
        temp: u32,
        addrs: &[EntityAddress],
    ) -> bool {
        self.delete(key);
        if !self.insert(key, addrs) {
            return false;
        }
        self.set_temperature(key, temp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::fingerprint::entity_key;

    fn addrs(n: u32) -> Vec<EntityAddress> {
        (0..n).map(|i| EntityAddress::new(i, i * 2)).collect()
    }

    fn key(i: u64) -> u64 {
        entity_key(&format!("entity-{i}"))
    }

    #[test]
    fn insert_then_lookup_returns_addresses() {
        let mut cf = CuckooFilter::default();
        let a = addrs(5);
        assert!(cf.insert(key(1), &a));
        let hit = cf.lookup(key(1)).expect("hit");
        assert_eq!(cf.addresses(hit), a);
    }

    #[test]
    fn missing_key_misses() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(1));
        assert!(cf.lookup(key(2)).is_none());
        assert!(!cf.contains(key(2)));
    }

    #[test]
    fn duplicate_insert_rejected() {
        let mut cf = CuckooFilter::default();
        assert!(cf.insert(key(1), &addrs(1)));
        assert!(!cf.insert(key(1), &addrs(2)));
        assert_eq!(cf.len(), 1);
    }

    #[test]
    fn delete_removes_and_allows_reinsert() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(3));
        assert!(cf.delete(key(1)));
        assert!(!cf.contains(key(1)));
        assert!(!cf.delete(key(1)), "double delete fails");
        assert!(cf.insert(key(1), &addrs(2)));
        let hit = cf.lookup(key(1)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 2);
    }

    #[test]
    fn delete_reclaims_arena_blocks() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(40)); // 3 blocks at BLOCK_CAP = 14
        let high_water = cf.arena().blocks_allocated();
        assert!(cf.delete(key(1)));
        assert_eq!(cf.arena().blocks_in_use(), 0, "blocks reclaimed");
        cf.insert(key(2), &addrs(40));
        assert_eq!(
            cf.arena().blocks_allocated(),
            high_water,
            "reinsert reuses freed blocks"
        );
    }

    #[test]
    // 20k keyed ops: minutes under Miri, no extra coverage of the
    // unsafe read beyond the small tests
    #[cfg_attr(miri, ignore)]
    fn insert_delete_churn_keeps_arena_bounded() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            ..CuckooConfig::default()
        });
        for cycle in 0..200u64 {
            for i in 0..50 {
                assert!(cf.insert(key(cycle * 50 + i), &addrs(3)));
            }
            for i in 0..50 {
                assert!(cf.delete(key(cycle * 50 + i)));
            }
        }
        assert_eq!(cf.len(), 0);
        assert_eq!(cf.arena().blocks_in_use(), 0);
        assert!(
            cf.arena().blocks_allocated() <= 64,
            "arena grew without bound: {}",
            cf.arena().blocks_allocated()
        );
    }

    #[test]
    fn temperature_bumps_on_lookup() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(1));
        assert_eq!(cf.temperature(key(1)), Some(0));
        cf.lookup(key(1));
        cf.lookup(key(1));
        assert_eq!(cf.temperature(key(1)), Some(2));
    }

    #[test]
    fn lookup_shared_matches_lookup() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(4));
        let via_shared = cf.lookup_shared(key(1)).expect("hit");
        assert_eq!(cf.addresses(via_shared), addrs(4));
        assert_eq!(cf.temperature(key(1)), Some(1), "shared lookup bumps temp");
        assert!(cf.lookup_shared(key(9)).is_none());
        assert_eq!(cf.stats().lookups, 2);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn no_false_negatives_at_high_load() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            ..CuckooConfig::default()
        });
        let n = 3000u64;
        for i in 0..n {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
        }
        for i in 0..n {
            assert!(cf.contains(key(i)), "false negative for {i}");
        }
        assert!(cf.stats().expansions > 0, "should have grown");
        assert!(cf.load_factor() <= 1.0);
    }

    #[test]
    fn expansion_preserves_addresses_and_temps() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 16,
            ..CuckooConfig::default()
        });
        cf.insert(key(0), &addrs(7));
        for _ in 0..5 {
            cf.lookup(key(0));
        }
        for i in 1..2000u64 {
            cf.insert(key(i), &addrs(1));
        }
        assert!(cf.stats().expansions >= 1);
        let hit = cf.lookup(key(0)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 7);
        assert_eq!(cf.temperature(key(0)), Some(6));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn interleaved_churn_survives_expansions() {
        // Regression for the expand() migration-retry entry loss: grow
        // through several expansions while deleting, then verify every
        // surviving key. Tiny table + deletes maximize retry pressure.
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 2,
            ..CuckooConfig::default()
        });
        let mut live = Vec::new();
        for i in 0..4000u64 {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
            live.push(i);
            if i % 3 == 0 {
                let victim = live.remove((i as usize / 3) % live.len());
                assert!(cf.delete(key(victim)), "delete {victim}");
            }
        }
        assert!(cf.stats().expansions >= 3, "not enough expansions");
        for &i in &live {
            let hit = cf.lookup(key(i));
            assert!(hit.is_some(), "entry {i} lost in migration");
            assert_eq!(cf.addresses(hit.unwrap()), addrs(1));
        }
        assert_eq!(cf.len(), live.len());
    }

    #[test]
    fn incremental_expansion_is_stepwise_and_lossless() {
        // 64 buckets × 4 slots = 256 slots: the 242nd insert crosses the
        // 0.94 threshold and starts a doubling. At one bucket per step
        // the remaining ~59 inserts cannot finish draining 64 buckets,
        // so the filter provably serves from both generations.
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            migration_step_buckets: 1,
            ..CuckooConfig::default()
        });
        let n = 300u64;
        for i in 0..n {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
        }
        assert!(cf.migration_pending(), "migration should still be in flight");
        for i in 0..n {
            assert!(cf.lookup(key(i)).is_some(), "{i} invisible mid-migration");
            assert_eq!(cf.occurrences(key(i)), 1, "{i} double-placed mid-migration");
        }
        // drive to completion in bounded steps; must terminate
        let mut steps = 0;
        while cf.migrate_step() {
            steps += 1;
            assert!(steps <= 65, "migration did not terminate");
        }
        assert!(!cf.migration_pending());
        for i in 0..n {
            assert!(cf.lookup(key(i)).is_some(), "{i} lost after migration");
            assert_eq!(cf.occurrences(key(i)), 1, "{i} double-placed");
        }
        assert_eq!(cf.len(), n as usize);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn step_zero_migrates_monolithically() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 16,
            migration_step_buckets: 0,
            ..CuckooConfig::default()
        });
        for i in 0..1000u64 {
            assert!(cf.insert(key(i), &addrs(1)));
            assert!(
                !cf.migration_pending(),
                "step 0 must complete the doubling within the insert"
            );
        }
        assert!(cf.stats().expansions >= 1);
    }

    #[test]
    fn maintain_completes_pending_migration() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            migration_step_buckets: 1,
            ..CuckooConfig::default()
        });
        for i in 0..300u64 {
            assert!(cf.insert(key(i), &addrs(1)));
        }
        assert!(cf.migration_pending());
        cf.maintain();
        assert!(!cf.migration_pending(), "maintain drains the migration");
        for i in 0..300u64 {
            assert!(cf.lookup(key(i)).is_some(), "{i} lost");
        }
    }

    #[test]
    fn delete_and_push_work_mid_migration() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            migration_step_buckets: 1,
            ..CuckooConfig::default()
        });
        for i in 0..300u64 {
            assert!(cf.insert(key(i), &addrs(1)));
        }
        assert!(cf.migration_pending());
        // key(0) was inserted long before the doubling started, key(299)
        // after — between them the two generations are both exercised.
        assert!(cf.delete(key(0)));
        assert!(!cf.contains_exact(key(0)));
        assert!(cf.push_address(key(299), EntityAddress::new(9, 9)));
        cf.maintain();
        assert!(!cf.contains_exact(key(0)), "delete survives the drain");
        let hit = cf.lookup(key(299)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 2, "pushed address survives");
        assert_eq!(cf.len(), 299);
    }

    #[test]
    fn push_address_grows_list() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(2));
        assert!(cf.push_address(key(1), EntityAddress::new(9, 9)));
        let hit = cf.lookup(key(1)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 3);
        assert!(!cf.push_address(key(2), EntityAddress::new(0, 0)));
    }

    #[test]
    fn maintain_sorts_hot_entities_front() {
        // Two entities forced into the same bucket: look one up many
        // times; after maintain() it must sit at position 0.
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1, // single bucket: everything collides
            slots: 4,
            load_threshold: 1.0,
            ..CuckooConfig::default()
        });
        let (a, b, c) = (key(10), key(20), key(30));
        cf.insert(a, &addrs(1));
        cf.insert(b, &addrs(1));
        cf.insert(c, &addrs(1));
        for _ in 0..10 {
            cf.lookup(c);
        }
        cf.lookup(a);
        cf.maintain();
        assert_eq!(cf.bucket_position(c), Some(0), "hottest first");
        // colder entities still findable
        assert!(cf.contains(a) && cf.contains(b));
    }

    #[test]
    fn plan_apply_sorts_hot_bucket() {
        // The epoch-style pair must reproduce maintain()'s result: plan
        // through &self, swap through a brief &mut.
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1,
            slots: 4,
            load_threshold: 1.0,
            ..CuckooConfig::default()
        });
        let (a, b, c) = (key(10), key(20), key(30));
        cf.insert(a, &addrs(1));
        cf.insert(b, &addrs(1));
        cf.insert(c, &addrs(1));
        for _ in 0..10 {
            cf.lookup(c);
        }
        let plans = cf.plan_maintenance();
        assert_eq!(plans.len(), 1, "one dirty bucket planned");
        assert!(cf.apply_bucket_plan(&plans[0]), "fresh plan applies");
        assert_eq!(cf.bucket_position(c), Some(0), "hottest first");
        assert!(cf.contains_exact(a) && cf.contains_exact(b));
        assert!(
            cf.plan_maintenance().is_empty(),
            "apply cleared the dirty flag"
        );
    }

    #[test]
    fn stale_bucket_plan_is_rejected() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1,
            slots: 4,
            load_threshold: 1.0,
            ..CuckooConfig::default()
        });
        cf.insert(key(10), &addrs(1));
        cf.insert(key(20), &addrs(1));
        for _ in 0..5 {
            cf.lookup(key(20));
        }
        let plans = cf.plan_maintenance();
        assert_eq!(plans.len(), 1);
        // a writer mutates the bucket between plan and apply
        cf.insert(key(30), &addrs(1));
        assert!(
            !cf.apply_bucket_plan(&plans[0]),
            "structurally stale plan must be rejected"
        );
        assert!(
            !cf.plan_maintenance().is_empty(),
            "rejected bucket stays dirty for the next round"
        );
        // nothing was corrupted by the rejected swap
        for k in [key(10), key(20), key(30)] {
            assert!(cf.contains_exact(k));
        }
    }

    #[test]
    fn sorting_disabled_is_a_noop() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1,
            slots: 4,
            load_threshold: 1.0,
            sort_by_temperature: false,
            ..CuckooConfig::default()
        });
        let (a, b) = (key(1), key(2));
        cf.insert(a, &addrs(1));
        cf.insert(b, &addrs(1));
        let before = cf.bucket_position(b);
        for _ in 0..10 {
            cf.lookup(b);
        }
        cf.maintain();
        assert_eq!(cf.bucket_position(b), before, "no reorder when disabled");
        assert!(cf.plan_maintenance().is_empty(), "no plans when disabled");
    }

    #[test]
    fn load_factor_tracks_len() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 256,
            ..CuckooConfig::default()
        });
        for i in 0..512u64 {
            cf.insert(key(i), &[]);
        }
        assert!((cf.load_factor() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn empty_address_list_insert() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &[]);
        let hit = cf.lookup(key(1)).unwrap();
        assert_eq!(hit.head, NIL);
        assert!(cf.addresses(hit).is_empty());
    }

    #[test]
    fn stats_count_probes() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(1));
        cf.lookup(key(1));
        let s = cf.stats();
        assert_eq!(s.lookups, 1);
        assert!(s.slots_probed >= 1);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn paper_scale_3148_entities_in_1024_buckets() {
        // §4.5.1: 3,148 entities, 1024 buckets x 4 slots, load 0.7686,
        // and a near-zero error rate.
        let mut cf = CuckooFilter::new(CuckooConfig::default());
        for i in 0..3148u64 {
            assert!(cf.insert(key(i), &addrs(1)));
        }
        assert_eq!(cf.buckets(), 1024, "no expansion needed at 0.77 load");
        let lf = cf.load_factor();
        assert!((lf - 0.7686).abs() < 1e-4, "load factor {lf}");
        // false-positive sweep over foreign keys
        let fp = (10_000..30_000u64).filter(|&i| cf.contains(key(i))).count();
        let rate = fp as f64 / 20_000.0;
        assert!(rate < 0.01, "fp rate {rate}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn hot_bytes_much_smaller_than_total() {
        let mut cf = CuckooFilter::default();
        for i in 0..1000u64 {
            cf.insert(key(i), &addrs(2));
        }
        assert!(cf.hot_bytes() * 4 < cf.memory_bytes());
    }

    #[test]
    fn clone_is_independent() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(2));
        let mut copy = cf.clone();
        copy.delete(key(1));
        assert!(cf.contains_exact(key(1)), "original unaffected by clone ops");
        assert!(!copy.contains_exact(key(1)));
    }

    #[test]
    fn kick_depth_histogram_counts_every_placement() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 8,
            ..CuckooConfig::default()
        });
        let n = 500u64;
        for i in 0..n {
            cf.insert(key(i), &addrs(1));
        }
        let s = cf.stats();
        let placements: u64 = s.kick_depth_hist.iter().sum();
        assert!(
            placements >= n,
            "every insert records a depth (migration re-placements add more): \
             {placements} < {n}"
        );
        assert!(s.kick_depth_hist[0] > 0, "most placements are kick-free");
        // the histogram's weighted depth floor is consistent with the
        // raw kick counter: bucket lower bounds 0,1,2,3,5,9,17,65
        let lower = [0u64, 1, 2, 3, 5, 9, 17, 65];
        let floor: u64 = s
            .kick_depth_hist
            .iter()
            .zip(lower)
            .map(|(c, lo)| c * lo)
            .sum();
        assert!(floor <= s.kicks, "floor {floor} exceeds kicks {}", s.kicks);
    }

    #[test]
    fn stats_merge_adds_kick_depths() {
        let mut a = CuckooStats::default();
        a.record_kick_depth(0);
        a.record_kick_depth(3);
        let mut b = CuckooStats::default();
        b.record_kick_depth(3);
        b.record_kick_depth(100);
        a.merge(b);
        assert_eq!(a.kick_depth_hist.iter().sum::<u64>(), 4);
        assert_eq!(a.kick_depth_hist[3], 2, "depths 3-4 share a bucket");
        assert_eq!(a.kick_depth_hist[7], 1, "65+ tail bucket");
    }

    #[test]
    fn estimated_fp_rate_tracks_load() {
        let mut cf = CuckooFilter::new(CuckooConfig::default());
        assert_eq!(cf.estimated_fp_rate(), 0.0, "empty filter, no collisions");
        for i in 0..3148u64 {
            cf.insert(key(i), &addrs(1));
        }
        let est = cf.estimated_fp_rate();
        // 12-bit fingerprints at ~0.77 load: about 2*4*0.77/4096 ≈ 0.15%
        assert!(est > 1e-4 && est < 1e-2, "estimate out of range: {est}");
    }

    #[test]
    fn default_config_is_incremental() {
        assert!(
            CuckooConfig::default().migration_step_buckets > 0,
            "incremental migration is the default; 0 is the monolithic opt-out"
        );
        assert!(crate::filter::blocklist::BLOCK_CAP >= 4);
    }

    #[test]
    fn export_restore_preserves_membership_addresses_and_temps() {
        let mut cf = CuckooFilter::default();
        for i in 0..200u64 {
            assert!(cf.insert(key(i), &addrs((i % 5 + 1) as u32)));
            cf.set_temperature(key(i), i as u32 * 3);
        }
        let mut exported = cf.export_entries();
        assert_eq!(exported.len(), 200);
        let mut restored = CuckooFilter::default();
        for (k, t, a) in &exported {
            assert!(restored.restore_entry(*k, *t, a));
        }
        assert_eq!(restored.len(), 200);
        let mut back = restored.export_entries();
        exported.sort();
        back.sort();
        assert_eq!(exported, back);
        assert_eq!(restored.temperature(key(7)), Some(21));
    }

    #[test]
    fn export_covers_both_generations_mid_migration() {
        let mut cfg = CuckooConfig::default();
        cfg.initial_buckets = 2;
        cfg.migration_step_buckets = 1;
        let mut cf = CuckooFilter::new(cfg);
        let mut n = 0u64;
        while !cf.migration_pending() {
            cf.insert(key(n), &addrs(1));
            n += 1;
        }
        assert!(cf.migration_pending(), "doubling must be in flight");
        let exported = cf.export_entries();
        assert_eq!(exported.len(), cf.len(), "every entry exactly once");
        let keys: std::collections::HashSet<u64> =
            exported.iter().map(|(k, _, _)| *k).collect();
        assert_eq!(keys.len(), exported.len(), "no duplicates across gens");
    }

    #[test]
    fn clear_resets_to_fresh_geometry() {
        let mut cf = CuckooFilter::default();
        for i in 0..500u64 {
            cf.insert(key(i), &addrs(3));
        }
        cf.clear();
        assert!(cf.is_empty());
        assert!(!cf.migration_pending());
        assert!(!cf.contains(key(1)));
        assert!(cf.insert(key(1), &addrs(2)), "usable after clear");
    }

    #[test]
    fn restore_entry_is_idempotent() {
        let mut cf = CuckooFilter::default();
        let a = addrs(4);
        assert!(cf.restore_entry(key(9), 11, &a));
        assert!(cf.restore_entry(key(9), 12, &a), "re-restore replaces");
        assert_eq!(cf.occurrences(key(9)), 1);
        assert_eq!(cf.temperature(key(9)), Some(12));
        let hit = cf.lookup(key(9)).expect("hit");
        assert_eq!(cf.addresses(hit), a);
    }
}
