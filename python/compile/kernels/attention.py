"""L1 Pallas kernel: masked single-head attention weights for fact ranking.

The generator stage of CFT-RAG ranks the retrieved hierarchy facts by
relevance to the query before filling the answer template. Ranking is a
single-head scaled dot-product attention: ``softmax(q . K^T / sqrt(D))``
with padding positions masked out. The artifact ships weights back to Rust,
which orders facts by weight.

TPU mapping: one request's (L, D) key tile fits VMEM outright
(L=64, D=64, f32 => 16 KiB), so the grid is over the batch dimension and
softmax is fused in-kernel — logits never round-trip to HBM, the exact
"keep the reduction in shared memory" trick a CUDA flash-attention port
would use, expressed with a BlockSpec instead of a threadblock.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, keys_ref, lens_ref, out_ref):
    """One grid step: full masked-softmax attention row for one request."""
    q = q_ref[...].astype(jnp.float32)        # [1, D]
    keys = keys_ref[...].astype(jnp.float32)  # [1, L, D]
    ln = lens_ref[...]                        # [1] int32
    d = q.shape[-1]
    logits = jnp.einsum("bd,bld->bl", q, keys) / jnp.sqrt(jnp.float32(d))
    mask = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) < ln[:, None]
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    w = jnp.exp(logits - m)
    w = jnp.where(mask, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    out_ref[...] = jnp.where(denom > 0.0, w / jnp.maximum(denom, 1e-30), 0.0)


@jax.jit
def attention_weights(q, keys, lens):
    """Masked attention weights of each query over its (padded) fact keys.

    Args:
      q:    [B, D] float — per-request query embeddings.
      keys: [B, L, D] float — per-request fact keys, zero-padded to L.
      lens: [B] int32 — valid fact count per request.

    Returns:
      [B, L] float32 — attention weights; padding positions exactly 0,
      all-zero rows for requests with lens == 0.
    """
    b, d = q.shape
    b2, l, d2 = keys.shape
    assert (b, d) == (b2, d2), f"shape mismatch q={q.shape} keys={keys.shape}"
    return pl.pallas_call(
        _attention_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, l, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, keys, lens.astype(jnp.int32))
