//! Mini property-based testing harness (offline replacement for `proptest`).
//!
//! Provides `forall`: run a property over many seeded random inputs; on
//! failure, attempt a bounded greedy shrink (caller supplies the shrinker)
//! and report the minimal failing seed/input. Deterministic: the failure
//! message includes the seed so a run can be reproduced by pinning
//! `CFT_PROPTEST_SEED`.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (env `CFT_PROPTEST_SEED` overrides).
    pub seed: u64,
    /// Max shrink attempts after a failure.
    pub max_shrinks: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("CFT_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Config { cases: 256, seed, max_shrinks: 500 }
    }
}

/// Run `prop` over `cfg.cases` inputs drawn by `gen`. On failure, shrink
/// with `shrink` (returns candidate smaller inputs) and panic with the
/// minimal input's debug representation.
pub fn forall<T, G, P, S>(cfg: Config, mut gen: G, mut prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = cfg.max_shrinks;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}): {best_msg}\n\
                 minimal input: {best:?}",
                seed = cfg.seed,
            );
        }
    }
}

/// `forall` with default config and no shrinking.
pub fn forall_simple<T, G, P>(cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    forall(
        Config { cases, ..Config::default() },
        gen,
        prop,
        |_| Vec::new(),
    );
}

/// Shrinker for vectors: halves, then drop-one prefixes.
pub fn shrink_vec<T: Clone>(xs: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if xs.is_empty() {
        return out;
    }
    out.push(xs[..xs.len() / 2].to_vec());
    out.push(xs[xs.len() / 2..].to_vec());
    if xs.len() <= 16 {
        for i in 0..xs.len() {
            let mut v = xs.to_vec();
            v.remove(i);
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall_simple(
            100,
            |rng| rng.below(1000),
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall_simple(
            100,
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn shrink_finds_smaller_vec() {
        // Property: no vector contains 7. Shrinker should reduce to ~[7].
        let result = std::panic::catch_unwind(|| {
            forall(
                Config { cases: 200, seed: 1, max_shrinks: 300 },
                |rng| {
                    let n = rng.range(0, 20);
                    (0..n).map(|_| rng.below(10)).collect::<Vec<u64>>()
                },
                |xs| {
                    if xs.contains(&7) {
                        Err("contains 7".into())
                    } else {
                        Ok(())
                    }
                },
                |xs| shrink_vec(xs),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // shrunk input should be small (a handful of elements at most)
        let after = msg.split("minimal input: ").nth(1).unwrap();
        assert!(after.len() < 40, "not shrunk: {after}");
    }

    #[test]
    fn shrink_vec_produces_halves() {
        let v: Vec<u64> = (0..8).collect();
        let cands = shrink_vec(&v);
        assert!(cands.contains(&vec![0, 1, 2, 3]));
        assert!(cands.contains(&vec![4, 5, 6, 7]));
    }
}
