//! Bloom Filter T-RAG (paper §4.1): every node carries a Bloom filter of
//! its subtree's entities; a descent is pruned the moment a filter says
//! the entity cannot be below. Still traverses, but skips cold subtrees.

use std::sync::Arc;

use crate::filter::fingerprint::entity_key;
use crate::filter::tree_bloom::BloomForest;
use crate::forest::{EntityAddress, Forest, NodeIdx};
use crate::retrieval::Retriever;

/// Bloom-pruned retriever.
pub struct BloomTRag {
    forest: Arc<Forest>,
    blooms: BloomForest,
    fp_rate: f64,
    bytes: usize,
}

impl BloomTRag {
    /// Build subtree blooms over `forest` at the given FP rate.
    pub fn new(forest: Arc<Forest>, fp_rate: f64) -> Self {
        let blooms = BloomForest::build(&forest, fp_rate);
        let bytes = blooms.memory_bytes();
        BloomTRag { forest, blooms, fp_rate, bytes }
    }

    fn descend(
        &self,
        tree_idx: u32,
        node: NodeIdx,
        id: crate::forest::EntityId,
        key: u64,
        out: &mut Vec<EntityAddress>,
    ) {
        let tree = self.forest.tree(tree_idx);
        if tree.entity(node) == id {
            out.push(EntityAddress::new(tree_idx, node));
        }
        for &c in &tree.node(node).children {
            // prune: child's bloom covers child + its descendants
            if self.blooms.might_contain(tree_idx, c, key) {
                self.descend(tree_idx, c, id, key, out);
            }
        }
    }
}

impl Retriever for BloomTRag {
    fn name(&self) -> &'static str {
        "BF T-RAG"
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        let Some(id) = self.forest.entity_id(entity) else {
            return Vec::new();
        };
        let key = entity_key(entity);
        let mut out = Vec::new();
        for t in 0..self.forest.len() as u32 {
            if self.blooms.might_contain(t, 0, key) {
                self.descend(t, 0, id, key, &mut out);
            }
        }
        out
    }

    fn reindex(&mut self, forest: Arc<Forest>, _new_trees: &[u32]) {
        // per-node blooms are subtree-global: rebuild (the update cost
        // the CF design avoids — measured by benches/updates.rs)
        self.blooms = BloomForest::build(&forest, self.fp_rate);
        self.bytes = self.blooms.memory_bytes();
        self.forest = forest;
    }

    fn index_bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let names: Vec<_> = ["h", "a", "b", "c", "d"]
            .iter()
            .map(|n| f.intern(n))
            .collect();
        let mut t = Tree::with_root(names[0]);
        let a = t.add_child(0, names[1]);
        t.add_child(0, names[2]);
        t.add_child(a, names[3]);
        t.add_child(a, names[4]);
        f.add_tree(t);
        // second tree without "c"
        let mut t2 = Tree::with_root(names[2]);
        t2.add_child(0, names[4]);
        f.add_tree(t2);
        Arc::new(f)
    }

    #[test]
    fn agrees_with_scan() {
        let f = forest();
        let mut r = BloomTRag::new(f.clone(), 0.01);
        for name in ["h", "a", "b", "c", "d", "zzz"] {
            let want = f
                .entity_id(name)
                .map(|id| f.scan_addresses(id))
                .unwrap_or_default();
            assert_eq!(r.find(name), want, "{name}");
        }
    }

    #[test]
    fn reports_index_memory() {
        let r = BloomTRag::new(forest(), 0.01);
        assert!(r.index_bytes() > 0);
    }
}
