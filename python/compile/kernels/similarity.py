"""L1 Pallas kernel: tiled query x corpus similarity matmul.

This is the vector-search hot-spot of the CFT-RAG pipeline (Figure 1, the
"vector search" stage): a batch of normalized query embeddings ``q[B, D]``
is scored against a corpus shard ``docs[N, D]`` producing ``[B, N]`` cosine
scores from which Rust takes the top-k.

TPU mapping (DESIGN.md §Hardware-Adaptation): the corpus dimension N is
tiled into VMEM-sized blocks of ``block_n`` rows; the grid walks the blocks
so HBM->VMEM transfers of the corpus are expressed by the BlockSpec rather
than threadblocks (the CUDA idiom this replaces). Each grid step issues one
(B x D) . (D x block_n) contraction to the MXU with f32 accumulation.

VMEM footprint per step at B=8, D=64, block_n=256 (f32):
  q tile 8*64*4 = 2 KiB, doc tile 256*64*4 = 64 KiB, out tile 8*256*4 = 8 KiB
  => ~74 KiB, far under the ~16 MiB VMEM budget; block_n could grow to 8192
  before pressure, but 256 keeps the last-dim lane tiling (128) fed with
  two tiles per step which pipelines cleanly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _similarity_kernel(q_ref, docs_ref, out_ref):
    """One grid step: score the full query tile against one corpus block."""
    q = q_ref[...].astype(jnp.float32)          # [B, D]
    d = docs_ref[...].astype(jnp.float32)       # [block_n, D]
    # Contract over D on the MXU; accumulate in f32.
    out_ref[...] = jax.lax.dot_general(
        q, d,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def similarity_scores(q, docs, *, block_n=256):
    """Score queries against a corpus shard with a tiled Pallas matmul.

    Args:
      q:       [B, D] float — query embeddings.
      docs:    [N, D] float — corpus shard embeddings; N % block_n == 0
               (the store pads shards to the artifact shape).
      block_n: corpus rows per VMEM block.

    Returns:
      [B, N] float32 scores.
    """
    b, d = q.shape
    n, d2 = docs.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    if n < block_n:
        block_n = n
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"

    grid = (n // block_n,)
    return pl.pallas_call(
        _similarity_kernel,
        grid=grid,
        in_specs=[
            # Query tile is reused by every grid step (index 0).
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            # Corpus walks one block per step.
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, docs)
