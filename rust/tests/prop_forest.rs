//! Property tests on the pre-processing substrate: relation filtering
//! always yields a forest-buildable edge set, building preserves every
//! entity, and tree structure invariants hold.

use std::collections::HashSet;

use cft_rag::forest::{builder::build_trees, Forest};
use cft_rag::nlp::filter::filter_relations;
use cft_rag::util::proptest::{forall, forall_simple, shrink_vec, Config};
use cft_rag::util::rng::Rng;

fn gen_edges(rng: &mut Rng, max_nodes: u64, max_edges: usize) -> Vec<(String, String)> {
    let n = rng.range(0, max_edges + 1);
    (0..n)
        .map(|_| {
            (
                format!("n{}", rng.below(max_nodes)),
                format!("n{}", rng.below(max_nodes)),
            )
        })
        .collect()
}

#[test]
fn filtered_relations_are_acyclic_and_single_parent() {
    forall(
        Config { cases: 300, ..Config::default() },
        |rng| gen_edges(rng, 30, 80),
        |edges| {
            let filtered = filter_relations(edges);
            // no self edges
            if filtered.iter().any(|(c, p)| c == p) {
                return Err("self edge survived".into());
            }
            // no duplicates
            let set: HashSet<_> = filtered.iter().collect();
            if set.len() != filtered.len() {
                return Err("duplicate edge survived".into());
            }
            // acyclic: child->parent graph must topo-sort
            if has_cycle(&filtered) {
                return Err(format!("cycle survived: {filtered:?}"));
            }
            Ok(())
        },
        |edges| shrink_vec(edges),
    );
}

fn has_cycle(edges: &[(String, String)]) -> bool {
    // Kahn over child->parent edges
    let mut nodes: HashSet<&str> = HashSet::new();
    for (c, p) in edges {
        nodes.insert(c);
        nodes.insert(p);
    }
    let mut out: std::collections::HashMap<&str, Vec<&str>> = Default::default();
    let mut indeg: std::collections::HashMap<&str, usize> = Default::default();
    for n in &nodes {
        indeg.insert(n, 0);
    }
    for (c, p) in edges {
        out.entry(c.as_str()).or_default().push(p.as_str());
        *indeg.get_mut(p.as_str()).unwrap() += 1;
    }
    let mut queue: Vec<&str> = indeg
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(n, _)| *n)
        .collect();
    let mut seen = 0;
    while let Some(n) = queue.pop() {
        seen += 1;
        if let Some(ps) = out.get(n) {
            for p in ps {
                let d = indeg.get_mut(p).unwrap();
                *d -= 1;
                if *d == 0 {
                    queue.push(p);
                }
            }
        }
    }
    seen != nodes.len()
}

#[test]
fn building_preserves_every_entity() {
    forall_simple(
        200,
        |rng| gen_edges(rng, 25, 60),
        |edges| {
            let filtered = filter_relations(edges);
            let mut forest = Forest::new();
            build_trees(&mut forest, &filtered);
            let mut expected: HashSet<&str> = HashSet::new();
            for (c, p) in &filtered {
                expected.insert(c);
                expected.insert(p);
            }
            for name in &expected {
                let Some(id) = forest.entity_id(name) else {
                    return Err(format!("{name} missing from forest"));
                };
                if forest.scan_addresses(id).is_empty() {
                    return Err(format!("{name} has no address"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tree_structure_invariants() {
    forall_simple(
        200,
        |rng| gen_edges(rng, 20, 50),
        |edges| {
            let filtered = filter_relations(edges);
            let mut forest = Forest::new();
            let idxs = build_trees(&mut forest, &filtered);
            for &ti in &idxs {
                let tree = forest.tree(ti);
                // root is its own ancestor chain end
                if tree.node(0).parent.is_some() {
                    return Err("root has a parent".into());
                }
                for idx in tree.indices() {
                    let node = tree.node(idx);
                    // depth consistency
                    if let Some(p) = node.parent {
                        if tree.node(p).depth + 1 != node.depth {
                            return Err(format!("depth broken at node {idx}"));
                        }
                        if !tree.node(p).children.contains(&idx) {
                            return Err("parent/child link asymmetric".into());
                        }
                    }
                    // children point back
                    for &c in &node.children {
                        if tree.node(c).parent != Some(idx) {
                            return Err("child's parent wrong".into());
                        }
                    }
                }
                // node count = reachable from root (no orphans inside a tree)
                let reachable =
                    cft_rag::forest::traverse::Bfs::new(tree).count();
                if reachable != tree.len() {
                    return Err(format!(
                        "{} reachable of {} nodes",
                        reachable,
                        tree.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn every_forest_node_appears_in_exactly_one_tree_position() {
    forall_simple(
        100,
        |rng| gen_edges(rng, 15, 40),
        |edges| {
            let filtered = filter_relations(edges);
            let mut forest = Forest::new();
            build_trees(&mut forest, &filtered);
            // address_table covers total_nodes exactly once
            let table = forest.address_table();
            let total: usize = table.values().map(Vec::len).sum();
            if total != forest.total_nodes() {
                return Err(format!(
                    "address table {total} != nodes {}",
                    forest.total_nodes()
                ));
            }
            let mut seen = HashSet::new();
            for addrs in table.values() {
                for a in addrs {
                    if !seen.insert(a.pack()) {
                        return Err("duplicate address".into());
                    }
                }
            }
            Ok(())
        },
    );
}
