//! Pipeline configuration.

use std::time::Duration;

use crate::filter::cuckoo::CuckooConfig;

/// Which retrieval algorithm backs the pipeline (paper §4.1–4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Naive T-RAG: BFS every tree.
    Naive,
    /// Bloom Filter T-RAG.
    Bloom,
    /// Improved Bloom Filter T-RAG (skip near-leaf checks).
    Bloom2,
    /// Cuckoo Filter T-RAG (the paper's system).
    Cuckoo,
}

impl Algorithm {
    /// All four, in the paper's table order.
    pub const ALL: [Algorithm; 4] =
        [Algorithm::Naive, Algorithm::Bloom, Algorithm::Bloom2, Algorithm::Cuckoo];

    /// Table label.
    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::Naive => "Naive T-RAG",
            Algorithm::Bloom => "BF T-RAG",
            Algorithm::Bloom2 => "BF2 T-RAG",
            Algorithm::Cuckoo => "CF T-RAG",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_lowercase().as_str() {
            "naive" => Some(Algorithm::Naive),
            "bloom" | "bf" => Some(Algorithm::Bloom),
            "bloom2" | "bf2" => Some(Algorithm::Bloom2),
            "cuckoo" | "cf" => Some(Algorithm::Cuckoo),
            _ => None,
        }
    }
}

/// End-to-end pipeline configuration.
#[derive(Clone, Debug)]
pub struct RagConfig {
    /// Retrieval algorithm.
    pub algorithm: Algorithm,
    /// Hierarchy levels captured up/down in context (paper's n).
    pub context_levels: usize,
    /// Documents fetched by the vector-search stage.
    pub topk_docs: usize,
    /// Bloom baselines: per-node filter FP rate.
    pub bloom_fp_rate: f64,
    /// Cuckoo filter tuning. Of serving interest:
    /// `cuckoo.migration_step_buckets` bounds how long a shard write
    /// lock is held while the filter doubles under load — smaller steps
    /// mean tighter reader tail latency during growth; `0` opts back
    /// into the monolithic single-hold migration (bench comparison arm).
    pub cuckoo: CuckooConfig,
    /// Cuckoo filter shards (rounded up to a power of two). On the
    /// concurrent serving path (`make_concurrent_retriever`), `0` =
    /// auto (one shard per available core). The single-threaded
    /// `make_retriever` has no parallelism to win, so there `0` and `1`
    /// both select the classic unsharded filter (whose probe statistics
    /// the Figure-5 bench reads); only `shards > 1` shards it. Ignored
    /// by the non-Cuckoo baselines.
    pub shards: usize,
}

impl Default for RagConfig {
    fn default() -> Self {
        RagConfig {
            algorithm: Algorithm::Cuckoo,
            context_levels: 3,
            topk_docs: 3,
            bloom_fp_rate: 0.01,
            cuckoo: CuckooConfig::default(),
            shards: 0,
        }
    }
}

impl RagConfig {
    /// Resolve the configured shard count: `0` maps to the number of
    /// available cores (so coordinator read throughput scales with the
    /// worker pool by default), anything else passes through.
    pub fn resolved_shards(&self) -> usize {
        if self.shards == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.shards
        }
    }
}

/// Configuration of the distributed shard router (`router/`): which
/// coordinator backends to front, and the timeouts/health policy of the
/// scatter-gather query path. See `router/mod.rs` for the topology.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Backend addresses (`host:port`), each a TCP coordinator speaking
    /// the newline-delimited JSON protocol of `coordinator/tcp.rs`.
    /// Order matters only for deterministic tie-breaks in the ring.
    pub backends: Vec<String>,
    /// TCP connect timeout per backend attempt.
    pub connect_timeout: Duration,
    /// Per-backend request timeout (socket read/write): one slow
    /// backend degrades its portion of a fanned-out reply instead of
    /// stalling the whole merge.
    pub request_timeout: Duration,
    /// Active health-probe period (`\x01stats` round trip per backend);
    /// zero disables the prober thread (tests that want deterministic
    /// backend traffic, or ops setups with external health checking).
    pub probe_interval: Duration,
    /// Consecutive request failures before a backend is passively
    /// marked unhealthy (probes re-admit it on the next success).
    pub failure_threshold: u32,
    /// Backends tried per sub-request before giving up: the owner
    /// first, then the ring's failover order.
    pub max_attempts: usize,
    /// Idle pooled connections kept per backend.
    pub max_idle_conns: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            backends: Vec::new(),
            connect_timeout: Duration::from_secs(1),
            request_timeout: Duration::from_secs(5),
            probe_interval: Duration::from_millis(500),
            failure_threshold: 1,
            max_attempts: 3,
            max_idle_conns: 4,
        }
    }
}

impl RouterConfig {
    /// Convenience: a config fronting `backends` with default policy.
    pub fn for_backends<S: Into<String>>(
        backends: impl IntoIterator<Item = S>,
    ) -> Self {
        RouterConfig {
            backends: backends.into_iter().map(Into::into).collect(),
            ..RouterConfig::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_labels() {
        assert_eq!(Algorithm::parse("cf"), Some(Algorithm::Cuckoo));
        assert_eq!(Algorithm::parse("NAIVE"), Some(Algorithm::Naive));
        assert_eq!(Algorithm::parse("bf2"), Some(Algorithm::Bloom2));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(Algorithm::Cuckoo.label(), "CF T-RAG");
        assert_eq!(Algorithm::ALL.len(), 4);
    }

    #[test]
    fn migration_step_knob_flows_through() {
        use crate::filter::cuckoo::CuckooFilter;
        use crate::filter::fingerprint::entity_key;

        let mut cfg = RagConfig::default();
        assert!(
            cfg.cuckoo.migration_step_buckets > 0,
            "serving config must default to incremental expansion"
        );
        // The knob must change actual filter behavior, not just sit in
        // the struct: with 1-bucket steps a threshold crossing leaves
        // the doubling observably in flight after an insert burst...
        cfg.cuckoo.initial_buckets = 64;
        cfg.cuckoo.migration_step_buckets = 1;
        let mut incremental = CuckooFilter::new(cfg.cuckoo);
        for i in 0..300u64 {
            incremental.insert(entity_key(&format!("knob-{i}")), &[]);
        }
        assert!(
            incremental.migration_pending(),
            "1-bucket steps leave the doubling in flight"
        );
        // ...while 0 (monolithic opt-out) completes inside the insert.
        cfg.cuckoo.migration_step_buckets = 0;
        let mut monolithic = CuckooFilter::new(cfg.cuckoo);
        for i in 0..300u64 {
            monolithic.insert(entity_key(&format!("knob-{i}")), &[]);
        }
        assert!(!monolithic.migration_pending(), "0 = whole-table migration");
    }

    #[test]
    fn router_config_defaults_sane() {
        let cfg = RouterConfig::default();
        assert!(cfg.backends.is_empty());
        assert!(cfg.max_attempts >= 1);
        assert!(cfg.failure_threshold >= 1);
        assert!(!cfg.request_timeout.is_zero());
        let cfg = RouterConfig::for_backends(["a:1", "b:2"]);
        assert_eq!(cfg.backends, vec!["a:1".to_string(), "b:2".to_string()]);
    }

    #[test]
    fn shards_resolve() {
        let auto = RagConfig::default();
        assert_eq!(auto.shards, 0, "default is auto");
        assert!(auto.resolved_shards() >= 1);
        let fixed = RagConfig { shards: 8, ..RagConfig::default() };
        assert_eq!(fixed.resolved_shards(), 8);
    }
}
