//! Vector store: documents + their embeddings, laid out in fixed-size
//! shards matching the score artifact's `[shard_docs, D]` input shape.
//!
//! Shards are zero-padded; padding rows have zero embeddings and can
//! never win top-k over a real document (scores are cosine in [-1, 1]
//! and padding scores exactly 0 — real docs relevant to a query score
//! above 0, and ties are broken toward real ids).

use crate::data::corpus::Document;
use crate::error::Result;
use crate::runtime::engine::Engine;

/// A corpus embedded into score-ready shards.
pub struct VectorStore {
    docs: Vec<Document>,
    /// shard-major embeddings: each shard is `[shard_docs * D]` f32
    shards: Vec<Vec<f32>>,
    dim: usize,
    shard_docs: usize,
}

impl VectorStore {
    /// Embed `docs` with the engine (batched to the artifact batch size)
    /// and pack them into shards.
    pub fn build(engine: &dyn Engine, docs: Vec<Document>) -> Result<VectorStore> {
        let shape = engine.shape();
        let (b, l, d) = (shape.batch, shape.max_tokens, shape.embed_dim);

        let mut embeddings: Vec<f32> = Vec::with_capacity(docs.len() * d);
        for chunk in docs.chunks(b) {
            let mut tokens = vec![0i32; b * l];
            for (i, doc) in chunk.iter().enumerate() {
                tokens[i * l..(i + 1) * l].copy_from_slice(&doc.tokens(l));
            }
            let emb = engine.embed(&tokens)?;
            embeddings.extend_from_slice(&emb[..chunk.len() * d]);
        }

        // pack into zero-padded shards
        let per = shape.shard_docs;
        let nshards = docs.len().div_ceil(per).max(1);
        let mut shards = Vec::with_capacity(nshards);
        for s in 0..nshards {
            let mut shard = vec![0f32; per * d];
            let start = s * per;
            let end = ((s + 1) * per).min(docs.len());
            if start < end {
                shard[..(end - start) * d]
                    .copy_from_slice(&embeddings[start * d..end * d]);
            }
            shards.push(shard);
        }
        Ok(VectorStore { docs, shards, dim: d, shard_docs: per })
    }

    /// Number of documents.
    pub fn len(&self) -> usize {
        self.docs.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Raw shard embeddings (score artifact input).
    pub fn shard(&self, idx: usize) -> &[f32] {
        &self.shards[idx]
    }

    /// Document accessor.
    pub fn doc(&self, id: u32) -> &Document {
        &self.docs[id as usize]
    }

    /// Embedding dim.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Docs per shard.
    pub fn shard_docs(&self) -> usize {
        self.shard_docs
    }

    /// Approximate bytes held by shard embeddings.
    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.capacity() * 4).sum()
    }

    /// Dynamic update: embed and append one document (fills the next
    /// padding row of the last shard, or opens a new shard). The new
    /// document's id is returned and immediately searchable.
    pub fn push(&mut self, engine: &dyn Engine, mut doc: Document) -> Result<u32> {
        let shape = engine.shape();
        let (b, l, d) = (shape.batch, shape.max_tokens, shape.embed_dim);
        let mut tokens = vec![0i32; b * l];
        tokens[..l].copy_from_slice(&doc.tokens(l));
        let emb = engine.embed(&tokens)?;

        let id = self.docs.len() as u32;
        doc.id = id;
        let per = self.shard_docs;
        let shard_idx = id as usize / per;
        if shard_idx >= self.shards.len() {
            self.shards.push(vec![0f32; per * d]);
        }
        let row = id as usize % per;
        self.shards[shard_idx][row * d..(row + 1) * d]
            .copy_from_slice(&emb[..d]);
        self.docs.push(doc);
        Ok(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::corpus_from_texts;
    use crate::runtime::engine::{EngineShape, NativeEngine};

    fn small_engine() -> NativeEngine {
        NativeEngine::with_shape(EngineShape {
            batch: 4,
            max_tokens: 16,
            embed_dim: 16,
            shard_docs: 8,
            max_facts: 8,
        })
    }

    fn texts(n: usize) -> Vec<String> {
        (0..n)
            .map(|i| format!("document number {i} about topic {}.", i % 3))
            .collect()
    }

    #[test]
    fn builds_shards_with_padding() {
        let e = small_engine();
        let store = VectorStore::build(&e, corpus_from_texts(&texts(10))).unwrap();
        assert_eq!(store.len(), 10);
        assert_eq!(store.shards(), 2, "10 docs over 8-doc shards");
        // padding rows in shard 1 are zero
        let sh = store.shard(1);
        let pad_row = &sh[2 * 16..3 * 16];
        assert!(pad_row.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_corpus_one_empty_shard() {
        let e = small_engine();
        let store = VectorStore::build(&e, Vec::new()).unwrap();
        assert_eq!(store.len(), 0);
        assert_eq!(store.shards(), 1);
        assert!(store.is_empty());
    }

    #[test]
    fn embeddings_are_row_aligned() {
        let e = small_engine();
        let docs = corpus_from_texts(&texts(3));
        let store = VectorStore::build(&e, docs).unwrap();
        // row 0 of shard 0 must be nonzero (a real embedding)
        let row0 = &store.shard(0)[..16];
        assert!(row0.iter().any(|&v| v != 0.0));
    }
}
