//! Text substrate: normalization, sentence splitting, stopwords, and the
//! hash tokenizer feeding the L2 embedder artifact.

pub mod normalize;
pub mod stopwords;
pub mod tokenizer;
