//! PJRT runtime layer: artifact manifest + executable loading/execution.
//!
//! `make artifacts` (Python, build time) produces `artifacts/*.hlo.txt`;
//! this module loads them once and serves typed execute calls to the
//! vector-search and generation stages. Start-to-finish request handling
//! never touches Python.

pub mod artifact;
pub mod engine;
pub mod client;

pub use artifact::{default_dir, Manifest};
pub use client::Runtime;
pub use engine::{Engine, EngineShape, NativeEngine, PjrtEngine};
