//! Clock shim: `Instant` that reads **virtual time** inside a model
//! run (`--features modelcheck`) and the real monotonic clock
//! everywhere else. Deadline arithmetic in the coordinator (batch
//! deadlines, bounded submit waits) goes through this type, which is
//! what lets the model checker explore a 5-second production timeout
//! in zero wall-clock time.
//!
//! Rule of thumb under the feature: an `Instant` must not cross the
//! model boundary — arithmetic mixing a real and a virtual instant
//! panics rather than returning a nonsense duration.

pub use std::time::Duration;

#[cfg(not(feature = "modelcheck"))]
pub use std::time::Instant;

#[cfg(feature = "modelcheck")]
pub use shim::Instant;

#[cfg(feature = "modelcheck")]
mod shim {
    use std::cmp::Ordering as CmpOrdering;
    use std::ops::{Add, Sub};
    use std::time::Duration;

    use crate::modelcheck::managed;

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    enum Repr {
        Real(std::time::Instant),
        Virtual(u128),
    }

    /// Drop-in [`std::time::Instant`]; virtual inside a model run.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct Instant(Repr);

    impl Instant {
        /// Scheduler virtual time on a model vthread, the monotonic
        /// clock otherwise.
        pub fn now() -> Instant {
            match managed() {
                Some((sh, _)) => Instant(Repr::Virtual(sh.now_ns())),
                None => Instant(Repr::Real(std::time::Instant::now())),
            }
        }

        /// See [`std::time::Instant::elapsed`].
        pub fn elapsed(&self) -> Duration {
            Instant::now() - *self
        }

        /// See [`std::time::Instant::duration_since`] (saturating).
        pub fn duration_since(&self, earlier: Instant) -> Duration {
            *self - earlier
        }
    }

    impl Add<Duration> for Instant {
        type Output = Instant;
        fn add(self, rhs: Duration) -> Instant {
            match self.0 {
                Repr::Real(t) => Instant(Repr::Real(t + rhs)),
                Repr::Virtual(ns) => {
                    Instant(Repr::Virtual(ns + rhs.as_nanos()))
                }
            }
        }
    }

    impl Sub<Instant> for Instant {
        type Output = Duration;
        fn sub(self, rhs: Instant) -> Duration {
            match (self.0, rhs.0) {
                (Repr::Real(a), Repr::Real(b)) => {
                    a.saturating_duration_since(b)
                }
                (Repr::Virtual(a), Repr::Virtual(b)) => {
                    Duration::from_nanos(a.saturating_sub(b) as u64)
                }
                _ => panic!(
                    "sync::time::Instant: arithmetic mixing a real and \
                     a virtual instant (an Instant crossed the model \
                     boundary)"
                ),
            }
        }
    }

    impl PartialOrd for Instant {
        fn partial_cmp(&self, other: &Instant) -> Option<CmpOrdering> {
            Some(self.cmp(other))
        }
    }

    impl Ord for Instant {
        fn cmp(&self, other: &Instant) -> CmpOrdering {
            match (self.0, other.0) {
                (Repr::Real(a), Repr::Real(b)) => a.cmp(&b),
                (Repr::Virtual(a), Repr::Virtual(b)) => a.cmp(&b),
                _ => panic!(
                    "sync::time::Instant: comparison mixing a real and \
                     a virtual instant (an Instant crossed the model \
                     boundary)"
                ),
            }
        }
    }
}
