//! Hot-entity reply cache for the router's query path (ROADMAP:
//! "Hot-entity result caching, router and backend side").
//!
//! The paper's temperature mechanism exists because entity mention
//! skew is heavy: under Zipf load a handful of hot entities dominate
//! retrieval. Once retrieval itself is fast, the next multiplier is
//! not doing the fan-out at all — a reply cache in front of the fleet.
//! The hard part is never serving a stale reply, so the design is
//! invalidation-first:
//!
//! * **Epoch in the key.** Entries are keyed on `(query text,
//!   normalized entity set, partition epoch)`. A membership change
//!   rolls the epoch, so every old entry becomes unreachable even
//!   before the wholesale flush the rebalance path also performs
//!   (belt *and* suspenders). The query text rides in the key because
//!   a backend's generated answer depends on the phrasing, not only
//!   the entity set — two phrasings of the same entities must not
//!   share an entry.
//! * **Exact, synchronous point invalidation.** The router's
//!   `\x01insert`/`\x01delete` broadcast path calls
//!   [`ReplyCache::invalidate_entity`] after the backends applied the
//!   write and *before* the quorum ack returns — a client that saw
//!   the ack can never read the pre-write reply (the
//!   write-ack-implies-invalidated promise in `docs/PROTOCOL.md`).
//! * **Fill-race guard.** A fill races concurrent invalidation: the
//!   reply was assembled from backend state read *before* a
//!   `\x01delete` landed, and a naive insert after the delete's
//!   eviction would resurrect the stale reply. Every lookup returns a
//!   [`FillToken`] capturing the invalidation event counter;
//!   [`ReplyCache::admit`] re-checks under the cache lock that no
//!   flush and no point invalidation of the entry's entities happened
//!   since the token was minted, and declines the fill otherwise.
//!   The `modelcheck_schedules.rs` cache schedules explore exactly
//!   this window.
//! * **Failover-aware fill.** The caller only admits replies whose
//!   `ok:true`/`degraded:false` — a reply assembled from a degraded
//!   scatter is missing facts and must not be pinned into the cache
//!   (enforced at the call site in `scatter.rs`; the cache itself
//!   additionally refuses non-`ok` replies).
//!
//! Admission is **frequency-driven, not recency-driven** (an LFU-ish
//! sketch, per ROADMAP — not plain LRU): a [`FreqSketch`] — a small
//! count-min sketch whose rows hash with the filter's own fingerprint
//! family ([`rendezvous_score`]) — estimates how hot a key is. A new
//! reply is admitted only by evicting strictly colder entries; a
//! one-hit-wonder never displaces a hot entry. Capacity is counted in
//! approximate heap **bytes** (`RouterConfig::cache_capacity_bytes`),
//! not entries, so one giant merged reply cannot blow the budget.
//!
//! The sixth executable elasticity contract
//! ([`CACHE_EPOCH_COHERENT`](crate::router::contracts::CACHE_EPOCH_COHERENT))
//! is checked at every fill and hit site: no cache entry outlives its
//! admission epoch.

use std::collections::HashMap;

use crate::filter::fingerprint::rendezvous_score;
use crate::router::contracts;
use crate::sync::Mutex;
use crate::util::json::Json;
use crate::util::rng::fnv1a;

/// Count-min rows. Four independent hash rows keep the over-estimate
/// bias low at this sketch size.
const SKETCH_ROWS: usize = 4;

/// Counters per row (power of two so the row hash is a mask).
const SKETCH_COLS: usize = 1024;

/// Halve every sketch counter after this many increments — the aging
/// that turns raw counts into a sliding-window temperature, same idea
/// as the filter's temperature decay.
const SKETCH_AGE_EVERY: u64 = (SKETCH_COLS as u64) * 8;

/// Fixed per-entry overhead charged against the byte budget on top of
/// the measured key/reply strings (map slots, indexes, bookkeeping).
const ENTRY_OVERHEAD_BYTES: usize = 128;

/// Row seeds: fnv1a of literal row names, mixed per-row through
/// [`rendezvous_score`] — the same fingerprint hash family the filter
/// shards and the ring routes with, so the sketch inherits its tested
/// independence properties instead of inventing a new mixer.
fn row_seed(row: usize) -> u64 {
    fnv1a(b"reply-cache-sketch-row") ^ (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// LFU-ish frequency sketch: a count-min sketch with saturating 8-bit
/// counters and periodic halving. `estimate` over-counts (never
/// under-counts) until saturation, which is the safe direction for an
/// admission filter — a cold key can look warm and waste a slot, but a
/// hot key can never look cold and be refused.
#[derive(Debug)]
struct FreqSketch {
    rows: Vec<[u8; SKETCH_COLS]>,
    increments: u64,
}

impl FreqSketch {
    fn new() -> FreqSketch {
        FreqSketch { rows: vec![[0u8; SKETCH_COLS]; SKETCH_ROWS], increments: 0 }
    }

    fn slot(row: usize, key: u64) -> usize {
        (rendezvous_score(key, row_seed(row)) as usize) & (SKETCH_COLS - 1)
    }

    fn touch(&mut self, key: u64) {
        for (i, row) in self.rows.iter_mut().enumerate() {
            let c = &mut row[Self::slot(i, key)];
            *c = c.saturating_add(1);
        }
        self.increments += 1;
        if self.increments >= SKETCH_AGE_EVERY {
            self.increments = 0;
            for row in &mut self.rows {
                for c in row.iter_mut() {
                    *c >>= 1;
                }
            }
        }
    }

    fn estimate(&self, key: u64) -> u8 {
        self.rows
            .iter()
            .enumerate()
            .map(|(i, row)| row[Self::slot(i, key)])
            .min()
            .unwrap_or(0)
    }
}

/// One cached reply. The full key material is stored and compared on
/// hit — a 64-bit slot-key collision must miss, never serve another
/// query's reply.
#[derive(Debug)]
struct Entry {
    query: String,
    /// Sorted, deduplicated entity names — the normalized entity set.
    entities: Vec<String>,
    /// The membership epoch this reply was admitted under. A hit is
    /// only valid at the same serving epoch (contract
    /// `cache-epoch-coherent`).
    epoch: u64,
    reply: Json,
    bytes: usize,
}

/// Opaque proof of *when* a lookup happened: the invalidation event
/// count at miss time. [`ReplyCache::admit`] uses it to decline fills
/// that raced an invalidation — see the module docs' fill-race guard.
#[derive(Clone, Copy, Debug)]
pub struct FillToken {
    events: u64,
}

/// Outcome of an [`ReplyCache::admit`] attempt, for metrics and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Admission {
    /// The reply is now cached.
    pub admitted: bool,
    /// Capacity-driven evictions performed to make room (0 when the
    /// fill was declined or nothing had to move).
    pub evicted: usize,
}

#[derive(Debug, Default)]
struct Inner {
    entries: HashMap<u64, Entry>,
    /// entity-key (`fnv1a` of the name) → slot keys of entries whose
    /// entity set contains it: the point-invalidation index.
    by_entity: HashMap<u64, Vec<u64>>,
    sketch: Option<FreqSketch>,
    bytes: usize,
    /// Monotonic invalidation event counter ([`FillToken`] source).
    events: u64,
    /// `events` value at the last wholesale flush.
    flushed_at: u64,
    /// entity-key → `events` value at its last point invalidation.
    /// Cleared wholesale by a flush (`flushed_at` supersedes every
    /// per-key stamp), which bounds it: every membership epoch roll
    /// flushes, so the map never outgrows one epoch's write set.
    invalidated: HashMap<u64, u64>,
}

impl Inner {
    fn sketch(&mut self) -> &mut FreqSketch {
        self.sketch.get_or_insert_with(FreqSketch::new)
    }

    fn remove_slot(&mut self, slot: u64) -> bool {
        let Some(entry) = self.entries.remove(&slot) else {
            return false;
        };
        self.bytes = self.bytes.saturating_sub(entry.bytes);
        for e in &entry.entities {
            let k = fnv1a(e.as_bytes());
            if let Some(slots) = self.by_entity.get_mut(&k) {
                slots.retain(|&s| s != slot);
                if slots.is_empty() {
                    self.by_entity.remove(&k);
                }
            }
        }
        true
    }
}

/// The router-side reply cache. Shared by reference from the `Router`;
/// all methods take `&self` and serialize on one internal mutex — the
/// critical sections are map probes, far cheaper than the backend
/// round trip a hit saves.
#[derive(Debug)]
pub struct ReplyCache {
    capacity_bytes: usize,
    inner: Mutex<Inner>,
}

/// Slot key of `(query, entities, epoch)`: fnv1a over the full key
/// material with unambiguous separators (entity names cannot contain
/// `\n` on the wire — the broadcast path rejects them — but the hash
/// does not rely on that: the stored entry is compared field by field
/// on every hit).
fn slot_key(query: &str, entities: &[String], epoch: u64) -> u64 {
    let mut material =
        String::with_capacity(query.len() + entities.iter().map(|e| e.len() + 1).sum::<usize>() + 8);
    material.push_str(query);
    for e in entities {
        material.push('\n');
        material.push_str(e);
    }
    fnv1a(material.as_bytes()) ^ rendezvous_score(epoch, row_seed(SKETCH_ROWS))
}

impl ReplyCache {
    /// New cache bounded by `capacity_bytes` of approximate entry
    /// heap. `0` disables the cache entirely: every method is a cheap
    /// no-op and [`ReplyCache::enabled`] is false.
    pub fn new(capacity_bytes: usize) -> ReplyCache {
        ReplyCache { capacity_bytes, inner: Mutex::new(Inner::default()) }
    }

    /// Whether this cache can ever hold an entry.
    pub fn enabled(&self) -> bool {
        self.capacity_bytes > 0
    }

    /// Approximate heap bytes of the cached entries (the `cache_bytes`
    /// gauge).
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `(query, entities, epoch)`; `entities` must be the
    /// normalized (sorted, deduplicated) entity set. Returns the
    /// cached reply on a hit, plus the [`FillToken`] an eventual
    /// [`ReplyCache::admit`] of a freshly assembled reply must carry.
    /// Every lookup — hit or miss — warms the frequency sketch, so
    /// admission temperature tracks demand, not cache contents.
    pub fn lookup(
        &self,
        query: &str,
        entities: &[String],
        epoch: u64,
    ) -> (Option<Json>, FillToken) {
        if !self.enabled() {
            return (None, FillToken { events: 0 });
        }
        let slot = slot_key(query, entities, epoch);
        let mut inner = self.inner.lock().unwrap();
        inner.sketch().touch(slot);
        let token = FillToken { events: inner.events };
        let hit = inner.entries.get(&slot).and_then(|e| {
            let matches =
                e.query == query && e.entities == entities && e.epoch == epoch;
            if matches {
                // contract (6): a served entry's admission epoch equals
                // the serving epoch of the membership snapshot in hand
                contracts::check_cache_epoch(e.epoch, epoch);
                Some(e.reply.clone())
            } else {
                None // slot-key collision: miss, never cross-serve
            }
        });
        (hit, token)
    }

    /// Try to cache `reply` for `(query, entities, epoch)`. Declined
    /// (returning `admitted: false`) when:
    ///
    /// * the cache is disabled, the reply is not `ok:true`, or the
    ///   entry alone exceeds the whole byte budget;
    /// * an invalidation (wholesale or of any of the entry's entities)
    ///   happened after `token` was minted — the fill-race guard;
    /// * making room would require evicting an entry at least as hot
    ///   as this one (the LFU-ish admission policy).
    pub fn admit(
        &self,
        query: &str,
        entities: &[String],
        epoch: u64,
        reply: &Json,
        token: FillToken,
    ) -> Admission {
        let declined = Admission { admitted: false, evicted: 0 };
        if !self.enabled() || reply.get("ok") != Some(&Json::Bool(true)) {
            return declined;
        }
        let slot = slot_key(query, entities, epoch);
        let mut inner = self.inner.lock().unwrap();

        // fill-race guard: the reply in hand was assembled from
        // backend state read before `token`; any newer invalidation
        // makes it unusable
        if inner.flushed_at > token.events {
            return declined;
        }
        if entities.iter().any(|e| {
            inner
                .invalidated
                .get(&fnv1a(e.as_bytes()))
                .is_some_and(|&at| at > token.events)
        }) {
            return declined;
        }

        // contract (6) at the fill site: the admission epoch is the
        // serving epoch the caller looked up under
        contracts::check_cache_epoch(epoch, epoch);

        let bytes = entry_bytes(query, entities, reply);
        if bytes > self.capacity_bytes {
            return declined;
        }
        // replacing an existing entry (same key, e.g. re-filled after
        // a point invalidation) releases its bytes first
        inner.remove_slot(slot);

        // LFU-ish admission: make room by evicting strictly colder
        // entries; if the coldest survivor is at least as hot as the
        // newcomer, the newcomer loses instead
        let heat = inner.sketch().estimate(slot);
        let mut evicted = 0usize;
        while inner.bytes + bytes > self.capacity_bytes {
            let victim = inner
                .entries
                .iter()
                .map(|(&s, e)| {
                    (s, inner.sketch.as_ref().map_or(0, |sk| sk.estimate(s)), e.bytes)
                })
                .min_by_key(|&(_, est, _)| est);
            match victim {
                Some((slot, est, _)) if est < heat => {
                    inner.remove_slot(slot);
                    evicted += 1;
                }
                _ => return Admission { admitted: false, evicted },
            }
        }

        inner.bytes += bytes;
        for e in entities {
            inner.by_entity.entry(fnv1a(e.as_bytes())).or_default().push(slot);
        }
        inner.entries.insert(
            slot,
            Entry {
                query: query.to_string(),
                entities: entities.to_vec(),
                epoch,
                reply: reply.clone(),
                bytes,
            },
        );
        Admission { admitted: true, evicted }
    }

    /// Point-invalidate every entry whose entity set contains
    /// `entity` — the `\x01insert`/`\x01delete` broadcast path calls
    /// this after the backends applied the write and before the quorum
    /// ack returns. Also arms the fill-race guard for the entity, so a
    /// fill whose token predates this call is declined. Returns the
    /// number of entries dropped.
    pub fn invalidate_entity(&self, entity: &str) -> usize {
        if !self.enabled() {
            return 0;
        }
        let key = fnv1a(entity.as_bytes());
        let mut inner = self.inner.lock().unwrap();
        inner.events += 1;
        let at = inner.events;
        inner.invalidated.insert(key, at);
        let slots = inner.by_entity.remove(&key).unwrap_or_default();
        let mut dropped = 0usize;
        for slot in slots {
            if inner.remove_slot(slot) {
                dropped += 1;
            }
        }
        dropped
    }

    /// Wholesale flush — the epoch-roll path (`Router::join`/`drain`,
    /// commit *and* abort). Drops every entry and arms the fill-race
    /// guard globally: any fill whose token predates the flush is
    /// declined. Returns the number of entries dropped.
    pub fn flush(&self) -> usize {
        if !self.enabled() {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.events += 1;
        inner.flushed_at = inner.events;
        inner.invalidated.clear();
        let dropped = inner.entries.len();
        inner.entries.clear();
        inner.by_entity.clear();
        inner.bytes = 0;
        dropped
    }
}

/// Approximate heap bytes of one entry: the key material, the
/// serialized reply, and a fixed bookkeeping overhead.
fn entry_bytes(query: &str, entities: &[String], reply: &Json) -> usize {
    query.len()
        + entities.iter().map(|e| e.len() + 24).sum::<usize>()
        + reply.to_string().len()
        + ENTRY_OVERHEAD_BYTES
}

/// Normalize a recognized mention list into the cache's entity-set key
/// form: sorted and deduplicated.
pub fn normalize_entities(mut entities: Vec<String>) -> Vec<String> {
    entities.sort();
    entities.dedup();
    entities
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply(tag: &str) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("answer", Json::Str(tag.to_string())),
        ])
    }

    fn ents(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn hit_roundtrip_and_epoch_separation() {
        let c = ReplyCache::new(64 * 1024);
        let e = ents(&["cardiology"]);
        let (miss, token) = c.lookup("q", &e, 0);
        assert!(miss.is_none());
        assert!(c.admit("q", &e, 0, &reply("a"), token).admitted);
        let (hit, _) = c.lookup("q", &e, 0);
        assert_eq!(hit.unwrap().get("answer"), Some(&Json::Str("a".into())));
        // same query at the next epoch is a distinct entry — an epoch
        // roll makes old entries unreachable even without the flush
        let (miss, _) = c.lookup("q", &e, 1);
        assert!(miss.is_none(), "old-epoch entry must not serve epoch 1");
        // distinct phrasings of the same entity set do not share
        let (miss, _) = c.lookup("q2", &e, 0);
        assert!(miss.is_none());
    }

    #[test]
    fn disabled_cache_is_inert() {
        let c = ReplyCache::new(0);
        assert!(!c.enabled());
        let e = ents(&["cardiology"]);
        let (miss, token) = c.lookup("q", &e, 0);
        assert!(miss.is_none());
        assert!(!c.admit("q", &e, 0, &reply("a"), token).admitted);
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.invalidate_entity("cardiology"), 0);
        assert_eq!(c.flush(), 0);
    }

    #[test]
    fn non_ok_replies_are_refused() {
        let c = ReplyCache::new(64 * 1024);
        let e = ents(&["cardiology"]);
        let (_, token) = c.lookup("q", &e, 0);
        let bad = Json::obj(vec![("ok", Json::Bool(false))]);
        assert!(!c.admit("q", &e, 0, &bad, token).admitted);
    }

    #[test]
    fn point_invalidation_drops_only_matching_entities() {
        let c = ReplyCache::new(64 * 1024);
        let ab = normalize_entities(ents(&["b", "a"]));
        let cd = normalize_entities(ents(&["d", "c"]));
        let (_, t1) = c.lookup("q1", &ab, 0);
        let (_, t2) = c.lookup("q2", &cd, 0);
        assert!(c.admit("q1", &ab, 0, &reply("ab"), t1).admitted);
        assert!(c.admit("q2", &cd, 0, &reply("cd"), t2).admitted);
        assert_eq!(c.len(), 2);
        // invalidating "a" drops the ab entry only
        assert_eq!(c.invalidate_entity("a"), 1);
        assert_eq!(c.len(), 1);
        assert!(c.lookup("q1", &ab, 0).0.is_none());
        assert!(c.lookup("q2", &cd, 0).0.is_some());
        // invalidating an uncached entity drops nothing but still
        // arms the fill guard (covered below)
        assert_eq!(c.invalidate_entity("zzz"), 0);
    }

    #[test]
    fn fill_race_is_declined_after_point_invalidation() {
        let c = ReplyCache::new(64 * 1024);
        let e = ents(&["cardiology"]);
        // the fill's token is minted at miss time...
        let (_, token) = c.lookup("q", &e, 0);
        // ...a delete lands while the reply is being assembled...
        c.invalidate_entity("cardiology");
        // ...so the (now stale) fill must be declined
        assert!(!c.admit("q", &e, 0, &reply("stale"), token).admitted);
        assert!(c.lookup("q", &e, 0).0.is_none());
        // a fill begun after the invalidation goes through
        let (_, fresh) = c.lookup("q", &e, 0);
        assert!(c.admit("q", &e, 0, &reply("fresh"), fresh).admitted);
    }

    #[test]
    fn fill_race_is_declined_after_flush() {
        let c = ReplyCache::new(64 * 1024);
        let e = ents(&["cardiology"]);
        let (_, token) = c.lookup("q", &e, 0);
        assert_eq!(c.flush(), 0);
        assert!(!c.admit("q", &e, 0, &reply("stale"), token).admitted);
        // unrelated entities are also guarded by a flush: it is an
        // epoch-roll-grade event
        let other = ents(&["oncology"]);
        assert!(!c.admit("q2", &other, 0, &reply("stale"), token).admitted);
    }

    #[test]
    fn flush_drops_everything() {
        let c = ReplyCache::new(64 * 1024);
        for i in 0..8 {
            let e = ents(&[&format!("e{i}")]);
            let (_, t) = c.lookup("q", &e, 0);
            assert!(c.admit("q", &e, 0, &reply("x"), t).admitted);
        }
        assert_eq!(c.len(), 8);
        assert!(c.bytes() > 0);
        assert_eq!(c.flush(), 8);
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn byte_budget_bounds_the_cache() {
        // capacity for roughly two entries of this shape
        let e0 = ents(&["e0"]);
        let (probe_cache, probe) =
            (ReplyCache::new(usize::MAX), reply("xxxxxxxxxxxxxxxx"));
        let (_, t) = probe_cache.lookup("q0", &e0, 0);
        probe_cache.admit("q0", &e0, 0, &probe, t);
        let per_entry = probe_cache.bytes();
        let c = ReplyCache::new(per_entry * 2 + per_entry / 2);

        // warm two keys hot, then try to push a cold third through
        for _ in 0..4 {
            c.lookup("q0", &ents(&["e0"]), 0);
            c.lookup("q1", &ents(&["e1"]), 0);
        }
        let (_, t0) = c.lookup("q0", &ents(&["e0"]), 0);
        assert!(c.admit("q0", &ents(&["e0"]), 0, &probe, t0).admitted);
        let (_, t1) = c.lookup("q1", &ents(&["e1"]), 0);
        assert!(c.admit("q1", &ents(&["e1"]), 0, &probe, t1).admitted);
        assert!(c.bytes() <= per_entry * 2 + per_entry / 2);

        // the cold newcomer cannot displace the hot incumbents...
        let (_, t2) = c.lookup("q2", &ents(&["e2"]), 0);
        let cold = c.admit("q2", &ents(&["e2"]), 0, &probe, t2);
        assert!(!cold.admitted, "cold fill must not evict hot entries");
        assert_eq!(c.len(), 2);

        // ...but once it is hotter than an incumbent, it displaces it
        for _ in 0..16 {
            c.lookup("q3", &ents(&["e3"]), 0);
        }
        let (_, t3) = c.lookup("q3", &ents(&["e3"]), 0);
        let hot = c.admit("q3", &ents(&["e3"]), 0, &probe, t3);
        assert!(hot.admitted, "hot fill must displace a colder entry");
        assert!(hot.evicted >= 1);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn oversized_entry_is_refused_outright() {
        let c = ReplyCache::new(64);
        let e = ents(&["cardiology"]);
        let (_, t) = c.lookup("q", &e, 0);
        let big = reply(&"x".repeat(4096));
        assert!(!c.admit("q", &e, 0, &big, t).admitted);
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn refill_after_invalidation_replaces_bytes_exactly() {
        let c = ReplyCache::new(64 * 1024);
        let e = ents(&["cardiology"]);
        let (_, t) = c.lookup("q", &e, 0);
        assert!(c.admit("q", &e, 0, &reply("v1"), t).admitted);
        let b1 = c.bytes();
        c.invalidate_entity("cardiology");
        assert_eq!(c.bytes(), 0);
        let (_, t) = c.lookup("q", &e, 0);
        assert!(c.admit("q", &e, 0, &reply("v1"), t).admitted);
        assert_eq!(c.bytes(), b1, "byte accounting must not drift");
    }

    #[test]
    fn sketch_estimates_track_frequency_and_age() {
        let mut s = FreqSketch::new();
        for _ in 0..10 {
            s.touch(42);
        }
        s.touch(7);
        assert!(s.estimate(42) >= 10);
        assert!(s.estimate(7) >= 1);
        assert!(
            s.estimate(42) > s.estimate(7),
            "hot key must estimate hotter"
        );
        // aging halves counters so temperature is a sliding window
        for i in 0..SKETCH_AGE_EVERY {
            s.touch(1000 + i);
        }
        assert!(s.estimate(42) <= 5, "aging must decay stale heat");
    }
}
