//! # CFT-RAG
//!
//! Reproduction of *"CFT-RAG: An Entity Tree Based Retrieval Augmented
//! Generation Algorithm With Cuckoo Filter"* (Li et al., 2025) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * Layer 3 (this crate): the improved Cuckoo Filter, the entity forest,
//!   all baseline retrievers, the pre-processing pipeline, the serving
//!   coordinator, and the distributed shard router (`router/`) with
//!   R-way replicated, key-partitioned backends — plus the benchmark
//!   harness.
//! * Layer 2/1 (build-time Python, `python/compile/`): the embedder /
//!   scorer / ranker JAX graphs and their Pallas kernels, AOT-lowered to
//!   `artifacts/*.hlo.txt` and executed here via the PJRT CPU client.
//!
//! Start at the repo-level `README.md` for the architecture map and
//! quickstart commands; the coordinator/router wire protocol is
//! specified in `docs/PROTOCOL.md`. `examples/quickstart.rs` is the
//! smallest end-to-end program. How the crate is verified — unit /
//! property tests, the `modelcheck` schedule suite, sanitizers, TCP
//! integration — is laid out in `docs/TESTING.md`.

// Unsafe hygiene: the crate has exactly three unsafe sites (the SWAR
// bucket-word read in `filter/cuckoo.rs`, the xla-gated
// `unsafe impl Send for Runtime` in `runtime/client.rs`, and the
// syscall layer of the serving reactor in `reactor/sys.rs` — epoll /
// poll(2) / nonblocking connect, the only place the crate talks to
// the kernel without std), all audited and documented with
// `// SAFETY:` contracts. Deny the implicit-unsafe footgun so any
// future unsafe fn must spell out its internal unsafe blocks. (`missing_debug_implementations` is applied per-module in
// the new `sync`/`modelcheck` layers rather than crate-wide: the
// pre-existing public surface has many intentionally Debug-less types
// and the clippy gate runs with `-D warnings`.)
#![deny(unsafe_op_in_unsafe_fn)]

pub mod util;
pub mod sync;
pub mod obs;
pub mod reactor;
#[cfg(feature = "modelcheck")]
pub mod modelcheck;
pub mod text;
pub mod nlp;
pub mod forest;
pub mod filter;
pub mod persist;
pub mod retrieval;
pub mod data;
pub mod error;
pub mod runtime;
pub mod vector;
pub mod llm;
pub mod rag;
pub mod coordinator;
pub mod router;
pub mod bench;
