//! The serving coordinator: request queue → dynamic batcher (embedding +
//! vector search at artifact batch size) → worker pool (NER, tree
//! retrieval, context, generation) → response channels. All Rust, all
//! threads; Python never runs here.
//!
//! Retrieval is **lock-free across workers** for the Cuckoo algorithm:
//! the pool shares an `Arc<dyn ConcurrentRetriever>` (a sharded filter
//! whose lookups take only per-shard read locks), so throughput scales
//! with `CoordinatorConfig::workers` instead of serializing on a global
//! retriever mutex. Baseline algorithms fall back to a mutex adapter.
//!
//! ```text
//!  submit() ─► [queue] ─► batcher thread ── embed+search (batch B) ──┐
//!                             │ (tick)                               ▼
//!                             ▼              worker pool (N threads):
//!                      maintainer thread     NER → retrieve → context
//!                      (retriever upkeep)     → generate ──► response
//! ```
//!
//! Retriever maintenance runs on its **own thread**: the batcher only
//! drops a non-blocking tick every `maintain_every` batches, so a slow
//! maintenance pass (bucket re-sorts, expansion draining) can never
//! stall embedding dispatch — pre-PR-2 it ran inline on the batcher and
//! did exactly that.

use crate::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use crate::sync::time::Instant;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::batcher::{collect_batch, BatchOutcome, BatchPolicy};
use crate::coordinator::metrics::Metrics;
use crate::data::corpus::Document;
use crate::obs::trace::{self, Sampler, Stage, TraceId};
use crate::error::{CftError, Result};
use crate::forest::Forest;
use crate::llm::cache::EmbedCache;
use crate::llm::generator::Generator;
use crate::llm::prompt::Prompt;
use crate::nlp::ner::GazetteerNer;
use crate::persist::{self, LogOp};
use crate::rag::config::RagConfig;
use crate::rag::pipeline::make_concurrent_retriever;
use crate::util::log;
use crate::retrieval::context::{generate_context, Context};
use crate::retrieval::context_cache::ContextCache;
use crate::retrieval::ConcurrentRetriever;
use crate::runtime::engine::Engine;
use crate::text::tokenizer::tokenize_padded;
use crate::util::stats::Timer;
use crate::vector::{search_topk, VectorStore};

/// Depth of the submit queue (jobs admitted but not yet batched).
const SUBMIT_QUEUE_DEPTH: usize = 1024;

/// How long [`Coordinator::submit`] may wait for queue space before
/// giving up with an explicit queue-full error.
const SUBMIT_FULL_TIMEOUT: Duration = Duration::from_secs(5);

/// Coordinator tuning.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads for the per-query stage.
    pub workers: usize,
    /// Batching policy for the embed/search stage.
    pub batch: BatchPolicy,
    /// Signal retriever maintenance every this many batches (0 = never).
    /// Maintenance itself runs on a dedicated thread, off the batcher.
    pub maintain_every: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            batch: BatchPolicy::default(),
            maintain_every: 16,
        }
    }
}

/// One served response.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub answer: String,
    pub entities: Vec<String>,
    pub fact_count: usize,
    pub docs: Vec<u32>,
    pub retrieval_time: Duration,
    pub total_time: Duration,
}

/// Where a job's response goes: the channel a blocking
/// [`Coordinator::submit`] caller waits on, or the callback the
/// nonblocking TCP front door registered via
/// [`Coordinator::submit_with`] (which queues the reply line back on
/// the connection's reactor).
enum Delivery {
    Channel(Sender<Result<ServeResponse>>),
    Callback(Box<dyn FnOnce(Result<ServeResponse>) + Send>),
}

impl Delivery {
    fn deliver(self, out: Result<ServeResponse>) {
        match self {
            // a caller that stopped listening is not an error
            Delivery::Channel(tx) => drop(tx.send(out)),
            Delivery::Callback(f) => f(out),
        }
    }
}

struct Job {
    query: String,
    enqueued: Instant,
    /// Sampling decision made at the front door ([`TraceId::NONE`]
    /// when untraced) — every stage below records its span against it.
    trace: TraceId,
    resp: Delivery,
}

struct WorkItem {
    job: Job,
    doc_hits: Vec<u32>,
    /// When the batcher handed this item to the worker queue — the
    /// start of the `worker_wait` span.
    dispatched: Instant,
}

/// The running coordinator.
///
/// `submit_tx`/`threads` sit behind mutexes so the coordinator can be
/// stopped through a shared reference ([`Coordinator::stop`]) — the
/// TCP layer and the shard router's tests hold it as `Arc<Coordinator>`
/// and need to tear down real in-process backends. The submit-path cost
/// is one uncontended lock to clone the sender.
pub struct Coordinator {
    submit_tx: Mutex<Option<SyncSender<Job>>>,
    metrics: Metrics,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Kept for the dynamic-update path: `\x01insert` addresses are
    /// validated against this forest before touching the index.
    forest: Arc<Forest>,
    /// The serving index, shared with the worker pool — the
    /// `\x01insert`/`\x01delete` control lines mutate it through the
    /// concurrent point-update methods (shard write locks only).
    retriever: Arc<dyn ConcurrentRetriever>,
    /// This backend's key partition, if the fleet is partitioned —
    /// consulted so a misrouted `\x01insert` NACKs instead of being
    /// indistinguishable from an idempotent retry. Behind a lock so an
    /// elastic membership change (`\x01repartition`) can install the
    /// next epoch's partition on a live backend.
    partition: std::sync::RwLock<Option<crate::rag::config::KeyPartition>>,
    /// The fleet membership epoch this backend currently serves
    /// (`partition_epoch` in the `\x01stats` payload): the router's
    /// health prober refuses to admit a backend whose epoch does not
    /// match the serving ring's.
    partition_epoch: std::sync::atomic::AtomicU64,
    /// Front-door connection cap ([`RagConfig::max_connections`]),
    /// read by `coordinator/tcp.rs` when it builds the listener's
    /// reactor config.
    max_connections: usize,
    /// Front-door idle reap timeout ([`RagConfig::idle_timeout`]).
    idle_timeout: Duration,
    /// This door's head-sampling policy
    /// ([`RagConfig::trace_sample_every`] /
    /// [`RagConfig::slow_query_threshold`]), consulted by the TCP
    /// layer per request line.
    sampler: Sampler,
    /// Process start, for the `uptime_s` stats field (real wall clock
    /// on purpose — uptime is operator-facing, never model-checked).
    started: std::time::Instant,
    /// Per-entity context memo ([`RagConfig::context_cache_entries`],
    /// 0 = disabled): shared with the worker pool, invalidated by the
    /// dynamic-update control lines *before* their acks return and
    /// flushed on `\x01repartition`/`\x01purge` — the backend half of
    /// the hot-entity caching story (`router/cache.rs` is the router
    /// half).
    context_cache: Arc<ContextCache>,
    /// Durable-state handle ([`RagConfig::data_dir`]): the op log every
    /// acked `\x01insert`/`\x01delete` is appended to *before* its ack
    /// is written, plus the snapshot machinery. `None` = volatile
    /// backend. Behind a mutex because appends must serialize anyway
    /// (one log file) and the ack path is already past the retriever's
    /// shard locks when it gets here.
    persist: Option<Mutex<persist::Store>>,
}

impl Coordinator {
    /// Build all stages and spawn the batcher + worker threads.
    ///
    /// Validates `rag_cfg` first ([`RagConfig::validate`]): a backend
    /// started with a key partition that contradicts its replication
    /// factor or algorithm fails here instead of silently serving the
    /// wrong slice of the key space.
    pub fn start(
        forest: Arc<Forest>,
        documents: Vec<Document>,
        engine: Arc<dyn Engine>,
        rag_cfg: RagConfig,
        cfg: CoordinatorConfig,
    ) -> Result<Coordinator> {
        rag_cfg.validate()?;
        let store = Arc::new(VectorStore::build(engine.as_ref(), documents)?);
        let ner = Arc::new(GazetteerNer::new(
            forest.interner().iter().map(|(_, n)| n),
        ));
        let retriever: Arc<dyn ConcurrentRetriever> =
            make_concurrent_retriever(forest.clone(), &rag_cfg);
        let metrics = Metrics::new();
        let cache = EmbedCache::new();
        let context_cache =
            Arc::new(ContextCache::new(rag_cfg.context_cache_entries));

        let (submit_tx, submit_rx) = sync_channel::<Job>(SUBMIT_QUEUE_DEPTH);
        let (work_tx, work_rx) = sync_channel::<WorkItem>(1024);
        let work_rx = Arc::new(Mutex::new(work_rx));
        // capacity 1: a busy maintainer coalesces pending ticks
        let (maint_tx, maint_rx) = sync_channel::<()>(1);

        let mut threads = Vec::new();

        // ---- maintainer thread: retriever upkeep, off the batcher ----
        // Exits when the batcher drops its tick sender at shutdown.
        {
            let retriever = retriever.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("cft-maintainer".into())
                    .spawn(move || {
                        while maint_rx.recv().is_ok() {
                            retriever.maintain_concurrent();
                        }
                    })
                    .expect("spawn maintainer"),
            );
        }

        // ---- batcher thread: embed + vector search at batch size ----
        {
            let engine = engine.clone();
            let store = store.clone();
            let metrics = metrics.clone();
            let topk = rag_cfg.topk_docs;
            threads.push(
                std::thread::Builder::new()
                    .name("cft-batcher".into())
                    .spawn(move || {
                        let mut batches = 0usize;
                        loop {
                            let (jobs, opened) =
                                match collect_batch(&submit_rx, cfg.batch) {
                                    BatchOutcome::Batch { items, opened } => {
                                        (items, opened)
                                    }
                                    BatchOutcome::Closed => break,
                                };
                            let collected = Instant::now();
                            for job in &jobs {
                                if !job.trace.is_sampled() {
                                    continue;
                                }
                                // submit_wait ends when the batch
                                // window opened (or on arrival, for a
                                // straggler that joined mid-window);
                                // batch_wait runs from there to
                                // collection — contiguous on purpose
                                let mid = if job.enqueued > opened {
                                    job.enqueued
                                } else {
                                    opened
                                };
                                trace::record(
                                    job.trace,
                                    Stage::SubmitWait,
                                    0,
                                    job.enqueued,
                                    mid.duration_since(job.enqueued),
                                );
                                trace::record(
                                    job.trace,
                                    Stage::BatchWait,
                                    jobs.len() as u32,
                                    mid,
                                    collected.duration_since(mid),
                                );
                            }
                            batches += 1;
                            metrics.record_batch(jobs.len());
                            if cfg.maintain_every > 0
                                && batches % cfg.maintain_every == 0
                            {
                                // non-blocking tick: maintenance happens
                                // on its own thread, never stalling the
                                // embed/search dispatch below
                                let _ = maint_tx.try_send(());
                            }
                            dispatch_batch(jobs, &engine, &store, topk, &work_tx);
                        }
                        // dropping work_tx closes the worker queue, and
                        // dropping maint_tx retires the maintainer
                    })
                    .expect("spawn batcher"),
            );
        }

        // ---- worker pool: per-query retrieval + generation ----
        for w in 0..cfg.workers.max(1) {
            let work_rx = work_rx.clone();
            let engine = engine.clone();
            let forest = forest.clone();
            let ner = ner.clone();
            let retriever = retriever.clone();
            let metrics = metrics.clone();
            let store = store.clone();
            let cache = cache.clone();
            let ctx_cache = context_cache.clone();
            let levels = rag_cfg.context_levels;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("cft-worker-{w}"))
                    .spawn(move || loop {
                        let item = {
                            let rx = work_rx.lock().unwrap();
                            rx.recv()
                        };
                        let Ok(item) = item else { break };
                        let out = serve_one(
                            &item, &engine, &forest, &ner, &retriever, &store,
                            &cache, &ctx_cache, levels,
                        );
                        match &out {
                            Ok(r) => metrics
                                .record_request(r.total_time, r.retrieval_time),
                            Err(_) => metrics.record_failure(),
                        }
                        item.job.resp.deliver(out);
                    })
                    .expect("spawn worker"),
            );
        }

        // ---- durable-state recovery (--data-dir) ----
        let mut key_partition = rag_cfg.key_partition;
        let mut partition_epoch =
            key_partition.as_ref().map_or(0, |p| p.epoch());
        let persist = match &rag_cfg.data_dir {
            None => None,
            Some(dir) => {
                let (store, recovery) = persist::Store::open(
                    dir,
                    rag_cfg.fsync_every,
                    rag_cfg.snapshot_interval_ops,
                )
                .map_err(CftError::Io)?;
                if let Some(snap) = &recovery.snapshot {
                    // the snapshot is authoritative over the forest
                    // build: entities deleted before it was cut must
                    // stay deleted, so the index is replaced wholesale
                    let restored = retriever
                        .restore_index(&snap.entries)
                        .ok_or_else(|| {
                            CftError::Config(format!(
                                "{} cannot restore a snapshot index",
                                retriever.name()
                            ))
                        })?;
                    log::info!(
                        "restored {restored} entries from {} (epoch {})",
                        dir.join(persist::SNAPSHOT_FILE).display(),
                        snap.partition_epoch
                    );
                }
                let mut replayed = 0usize;
                for op in &recovery.ops {
                    match op {
                        LogOp::Insert { entity, addr } => {
                            // every logged op was validated + acked
                            // before the crash; re-apply is idempotent
                            // and skips keys the configured partition no
                            // longer owns. Bounds are re-checked because
                            // a data dir paired with a different corpus
                            // must not plant addresses retrieval would
                            // panic on.
                            let in_forest = forest
                                .trees()
                                .get(addr.tree as usize)
                                .is_some_and(|t| {
                                    (addr.node as usize) < t.len()
                                });
                            if in_forest {
                                retriever.insert_occurrence(entity, *addr);
                                replayed += 1;
                            } else {
                                log::warn!(
                                    "op-log insert of {entity:?} at \
                                     ({}, {}) is outside this forest; \
                                     skipped (corpus changed?)",
                                    addr.tree,
                                    addr.node
                                );
                            }
                        }
                        LogOp::Delete { entity } => {
                            retriever.remove_entity_concurrent(entity);
                            replayed += 1;
                        }
                        LogOp::Epoch(_) => {}
                    }
                }
                if replayed > 0 || recovery.truncated_bytes > 0 {
                    log::info!(
                        "replayed {replayed} op(s) from {} ({} torn \
                         byte(s) truncated)",
                        dir.join(persist::OPLOG_FILE).display(),
                        recovery.truncated_bytes
                    );
                }
                if let Some(epoch) = recovery.recorded_epoch() {
                    // re-admit at the recorded membership epoch: the
                    // configured partition supplies the membership, the
                    // recovery supplies the epoch this backend last
                    // acked — what the router's EpochGate checks
                    key_partition =
                        key_partition.map(|p| p.with_epoch(epoch));
                    partition_epoch = epoch;
                }
                Some(Mutex::new(store))
            }
        };

        Ok(Coordinator {
            submit_tx: Mutex::new(Some(submit_tx)),
            metrics,
            threads: Mutex::new(threads),
            forest,
            retriever,
            partition: std::sync::RwLock::new(key_partition),
            partition_epoch: std::sync::atomic::AtomicU64::new(
                partition_epoch,
            ),
            max_connections: rag_cfg.max_connections,
            idle_timeout: rag_cfg.idle_timeout,
            sampler: Sampler::new(
                rag_cfg.trace_sample_every,
                rag_cfg.slow_query_threshold,
            ),
            started: std::time::Instant::now(),
            context_cache,
            persist,
        })
    }

    /// Submit a query; returns the channel the response will arrive on.
    ///
    /// Backpressure and lifecycle are explicit: a full request queue
    /// blocks for at most [`SUBMIT_FULL_TIMEOUT`] before failing with a
    /// queue-full error, and submitting to a stopped coordinator (or one
    /// whose batcher died) fails immediately — the pre-PR-2 behavior
    /// silently discarded the job on a closed queue and blocked forever
    /// on a full one.
    pub fn submit(&self, query: &str) -> Result<Receiver<Result<ServeResponse>>> {
        let (resp_tx, resp_rx) = crate::sync::mpsc::channel();
        let job = Job {
            query: query.to_string(),
            enqueued: Instant::now(),
            trace: TraceId::NONE,
            resp: Delivery::Channel(resp_tx),
        };
        // clone the sender under the lock, enqueue outside it: the
        // bounded full-queue wait must not serialize other submitters
        let queue = self
            .submit_tx
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| CftError::Coordinator("coordinator stopped".into()))?;
        enqueue(&queue, job, SUBMIT_FULL_TIMEOUT)?;
        Ok(resp_rx)
    }

    /// Submit a query whose response (or enqueue failure) is delivered
    /// through `done` instead of a channel — the nonblocking TCP front
    /// door's path: the calling reactor thread must never block, so a
    /// full request queue fails fast through the callback rather than
    /// waiting out [`SUBMIT_FULL_TIMEOUT`] like
    /// [`submit`](Coordinator::submit) does (over TCP, immediate
    /// backpressure beats a silently stalled accept loop).
    pub fn submit_with(
        &self,
        query: &str,
        done: Box<dyn FnOnce(Result<ServeResponse>) + Send>,
    ) {
        self.submit_traced(query, TraceId::NONE, done);
    }

    /// [`submit_with`](Coordinator::submit_with) carrying the front
    /// door's sampling decision: every pipeline stage below records
    /// its span against `trace` (a no-op branch when unsampled).
    pub fn submit_traced(
        &self,
        query: &str,
        trace: TraceId,
        done: Box<dyn FnOnce(Result<ServeResponse>) + Send>,
    ) {
        let queue = match self.submit_tx.lock().unwrap().clone() {
            Some(q) => q,
            None => {
                done(Err(CftError::Coordinator("coordinator stopped".into())));
                return;
            }
        };
        let job = Job {
            query: query.to_string(),
            enqueued: Instant::now(),
            trace,
            resp: Delivery::Callback(done),
        };
        match queue.try_send(job) {
            Ok(()) => {}
            Err(TrySendError::Disconnected(job)) => job.resp.deliver(Err(
                CftError::Coordinator(
                    "request queue closed (batcher gone)".into(),
                ),
            )),
            Err(TrySendError::Full(job)) => {
                job.resp.deliver(Err(CftError::Coordinator(format!(
                    "request queue full ({SUBMIT_QUEUE_DEPTH} pending)"
                ))))
            }
        }
    }

    /// Front-door connection cap this backend was configured with.
    pub fn max_connections(&self) -> usize {
        self.max_connections
    }

    /// Front-door idle reap timeout this backend was configured with.
    pub fn idle_timeout(&self) -> Duration {
        self.idle_timeout
    }

    /// Submit and wait.
    pub fn query_blocking(&self, query: &str) -> Result<ServeResponse> {
        self.submit(query)?
            .recv()
            .map_err(|_| CftError::Coordinator("coordinator stopped".into()))?
    }

    /// Metrics handle.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// This door's head-sampling policy (the TCP layer consults it per
    /// request line).
    pub fn sampler(&self) -> &Sampler {
        &self.sampler
    }

    /// Wall time since this coordinator started (the `uptime_s` stats
    /// field).
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Filter-internals snapshot of the serving index, when the
    /// retriever is Cuckoo-backed (`None` for the baselines) — the
    /// `"filter"` sub-object of the `\x01stats` payload.
    pub fn filter_telemetry(&self) -> Option<crate::filter::FilterTelemetry> {
        self.retriever.filter_telemetry()
    }

    /// Apply a dynamic entity-index **insert** (the `\x01insert` control
    /// line, `docs/PROTOCOL.md`): register one occurrence of `entity` at
    /// `(tree, node)`. The address is validated against this backend's
    /// forest — an occurrence pointing at a tree or node that does not
    /// exist would panic a later retrieval, so it is rejected here.
    /// Returns whether the index changed: an occurrence that is already
    /// indexed (a retried broadcast) is an idempotent `Ok(false)`.
    /// Errors when the address is invalid, the retriever cannot apply
    /// point updates, or this backend's key partition assigns the key
    /// elsewhere (a misrouted write must not ack).
    pub fn update_entity(&self, entity: &str, tree: u32, node: u32) -> Result<bool> {
        let t = self.forest.trees().get(tree as usize).ok_or_else(|| {
            CftError::Config(format!(
                "tree {tree} out of range ({} trees)",
                self.forest.len()
            ))
        })?;
        if (node as usize) >= t.len() {
            return Err(CftError::Config(format!(
                "node {node} out of range ({} nodes in tree {tree})",
                t.len()
            )));
        }
        if let Some(p) = self.partition.read().unwrap().as_ref() {
            if !p.owns(crate::filter::fingerprint::entity_key(entity)) {
                return Err(CftError::Config(format!(
                    "key {entity:?} is not in this backend's partition"
                )));
            }
        }
        let addr = crate::forest::EntityAddress::new(tree, node);
        match self.retriever.insert_occurrence(entity, addr) {
            Some(applied) => {
                if applied {
                    // invalidate-before-ack: the entity's memoized
                    // context reflects pre-write trees, and a racing
                    // fill holding an older token is declined — after
                    // this ack no reader can see the stale facts
                    self.context_cache.invalidate(entity);
                    // ack-after-durable: the log record is fsynced (at
                    // --fsync-every 1) before this returns, and a log
                    // failure propagates as an error so the client is
                    // never acked for a write that would not survive a
                    // crash. An idempotent no-op retry changes nothing
                    // and is not logged.
                    self.append_durable(&LogOp::Insert {
                        entity: entity.to_string(),
                        addr,
                    })?;
                }
                Ok(applied)
            }
            None => Err(CftError::Config(format!(
                "{} does not support dynamic point updates",
                self.retriever.name()
            ))),
        }
    }

    /// Apply a dynamic entity-index **delete** (the `\x01delete` control
    /// line, paper Algorithm 2): drop `entity` from the index entirely.
    /// Returns whether the entity was present — removing an absent (or,
    /// on a partitioned backend, un-owned) key is an idempotent
    /// `Ok(false)`. Errors only when the retriever cannot apply point
    /// updates at all.
    pub fn remove_entity(&self, entity: &str) -> Result<bool> {
        match self.retriever.remove_entity_concurrent(entity) {
            Some(existed) => {
                if existed {
                    // invalidate-before-ack, same contract as inserts
                    self.context_cache.invalidate(entity);
                    // durable before ack, same contract as inserts — a
                    // crash after this ack must not resurrect the entity
                    self.append_durable(&LogOp::Delete {
                        entity: entity.to_string(),
                    })?;
                }
                Ok(existed)
            }
            None => Err(CftError::Config(format!(
                "{} does not support dynamic point updates",
                self.retriever.name()
            ))),
        }
    }

    /// All indexed addresses of `entity` on this backend (the
    /// `\x01dump` control line) — the read half of the rebalancer's
    /// hinted handoff: a current replica dumps a key's address list so
    /// the router can replay it to a joining backend as `\x01insert`
    /// lines. Empty when the backend does not hold the key.
    pub fn dump_entity(&self, entity: &str) -> Vec<crate::forest::EntityAddress> {
        let mut out = Vec::new();
        self.retriever.find_concurrent(entity, &mut out);
        out
    }

    /// Install the next membership epoch's key partition (`None` =
    /// full index) — the `\x01repartition` control line. Changes which
    /// keys dynamic updates accept and the `partition_epoch` the
    /// backend reports; already-indexed entries keep serving until
    /// [`drop_disowned`](Coordinator::drop_disowned) reclaims them, so
    /// a repartitioned backend never answers with missing facts
    /// mid-rebalance. Errors when the serving retriever cannot
    /// repartition (Bloom/naive baselines).
    pub fn set_partition(
        &self,
        partition: Option<crate::rag::config::KeyPartition>,
        epoch: u64,
    ) -> Result<()> {
        let had_partition = self.partition.read().unwrap().is_some();
        if (partition.is_some() || had_partition)
            && !self.retriever.repartition_concurrent(partition.clone())
        {
            return Err(CftError::Config(format!(
                "{} cannot repartition (whole-tree annotations)",
                self.retriever.name()
            )));
        }
        *self.partition.write().unwrap() = partition;
        self.partition_epoch
            .store(epoch, std::sync::atomic::Ordering::Release);
        // ownership just changed wholesale; every memoized context is
        // suspect, and the flush also poisons in-flight fill tokens
        self.context_cache.flush();
        // Record the epoch the backend now serves, so a warm restart
        // re-admits at this epoch instead of the stale snapshot one.
        self.append_durable(&LogOp::Epoch(epoch))?;
        Ok(())
    }

    /// Drop every indexed key the current partition no longer owns
    /// (the `\x01purge` control line) — the incumbents' reclamation
    /// pass after a membership change, run once the router has admitted
    /// the new ring so no reader still routes the dropped keys here.
    /// Returns the number of keys removed (0 with no partition).
    pub fn drop_disowned(&self) -> Result<usize> {
        match self.retriever.drop_disowned_concurrent() {
            Some(n) => {
                // dropped keys may be memoized; flush before the ack so
                // no later query serves a reclaimed entity's context
                self.context_cache.flush();
                Ok(n)
            }
            None if self.partition.read().unwrap().is_none() => Ok(0),
            None => Err(CftError::Config(format!(
                "{} cannot drop disowned keys",
                self.retriever.name()
            ))),
        }
    }

    /// The fleet membership epoch this backend serves (0 = fleet start
    /// or unpartitioned).
    pub fn partition_epoch(&self) -> u64 {
        self.partition_epoch
            .load(std::sync::atomic::Ordering::Acquire)
    }

    /// Append one op to the durability log (no-op on a volatile
    /// backend). The record is durable when this returns (at
    /// `--fsync-every 1`); an I/O failure propagates so the caller
    /// never acks a write the disk did not take — the index may then be
    /// ahead of the log, which is safe (a replayed retry dedups) while
    /// the reverse would lose data. Also cuts an automatic snapshot
    /// when `--snapshot-interval-ops` says the log has grown enough.
    fn append_durable(&self, op: &LogOp) -> Result<()> {
        let Some(persist) = &self.persist else { return Ok(()) };
        let mut store = persist.lock().unwrap();
        store.record(op).map_err(|e| {
            CftError::Coordinator(format!(
                "durability log append failed (write NOT acked): {e}"
            ))
        })?;
        if store.should_snapshot() {
            // inline on the ack path by design: the interval amortizes
            // the pause, and a snapshot folding the log keeps replay
            // O(interval) instead of O(all ops since boot)
            self.snapshot_locked(&mut store)?;
        }
        Ok(())
    }

    /// Cut a snapshot into an already-locked store: export the live
    /// index, write it atomically at the current epoch, truncate the
    /// op log. Returns the number of entries captured.
    fn snapshot_locked(&self, store: &mut persist::Store) -> Result<usize> {
        let entries = self.retriever.export_index().ok_or_else(|| {
            CftError::Config(format!(
                "{} cannot export its index for snapshotting",
                self.retriever.name()
            ))
        })?;
        let n = entries.len();
        store
            .write_snapshot(self.partition_epoch(), entries)
            .map_err(|e| {
                CftError::Coordinator(format!("snapshot write failed: {e}"))
            })?;
        Ok(n)
    }

    /// Cut a snapshot now (the `\x01snapshot` control line). Returns
    /// the number of entries captured; errors on a volatile backend
    /// (no `--data-dir`) or when the retriever cannot export.
    pub fn trigger_snapshot(&self) -> Result<usize> {
        let Some(persist) = &self.persist else {
            return Err(CftError::Config(
                "no --data-dir configured; nothing to snapshot into".into(),
            ));
        };
        let mut store = persist.lock().unwrap();
        self.snapshot_locked(&mut store)
    }

    /// Per-entity context cache handle — the TCP layer reports its
    /// [`stats`](ContextCache::stats) in the `\x01stats` payload when
    /// the cache is enabled, and tests drive invalidation through it.
    pub fn context_cache(&self) -> &ContextCache {
        &self.context_cache
    }

    /// Durability counters for `\x01stats` (`None` = volatile backend).
    pub fn durability(&self) -> Option<persist::DurabilityCounters> {
        self.persist.as_ref().map(|p| p.lock().unwrap().counters())
    }

    /// Approximate heap bytes of the serving index — a key-partitioned
    /// backend reports roughly `R/N` of a full-index backend (the memory
    /// axis of the replication bench in `benches/concurrent.rs`).
    pub fn index_bytes(&self) -> usize {
        self.retriever.index_bytes()
    }

    /// Heap bytes backing **live** index entries only: after a
    /// membership change's drop pass this shrinks toward the
    /// `~R/N` bound even though freed arena capacity is retained
    /// (the memory axis of the join bench in `benches/concurrent.rs`).
    pub fn live_index_bytes(&self) -> usize {
        self.retriever.live_index_bytes()
    }

    /// True once [`stop`](Coordinator::stop) has closed the submit
    /// queue. The TCP layer checks this per request line so that a
    /// stopped coordinator *drops* its open connections like a dead
    /// process would — keeping them alive would let control lines
    /// (`\x01stats`) keep succeeding on a backend that can no longer
    /// serve, masking its death from the router's health prober.
    pub fn is_stopped(&self) -> bool {
        self.submit_tx.lock().unwrap().is_none()
    }

    /// Stop accepting work and join all threads — callable through a
    /// shared reference, so an `Arc<Coordinator>` held by TCP handler
    /// threads (or the router's in-process backend tests) can be torn
    /// down. Idempotent: later calls find the queue already closed and
    /// no threads left to join. In-flight jobs drain first (closing the
    /// queue lets the batcher finish what was admitted, then exit).
    pub fn stop(&self) {
        // close the queue; batcher exits, then workers, then maintainer
        let was_running = self.submit_tx.lock().unwrap().take().is_some();
        let mut threads = self.threads.lock().unwrap();
        for t in threads.drain(..) {
            let _ = t.join();
        }
        drop(threads);
        if was_running {
            // graceful shutdown cuts a final snapshot (once — the
            // idempotent re-entry path skips it), so the next boot
            // restores from the snapshot alone with an empty log
            if let Some(persist) = &self.persist {
                let mut store = persist.lock().unwrap();
                if let Err(e) = self.snapshot_locked(&mut store) {
                    log::warn!("shutdown snapshot failed: {e}");
                }
            }
        }
    }

    /// Stop and consume (the owned-coordinator form of [`stop`]).
    ///
    /// [`stop`]: Coordinator::stop
    pub fn shutdown(self) {
        self.stop();
    }
}

/// Enqueue one job with explicit full-queue and closed-queue behavior:
/// bounded blocking (poll + back off, up to `timeout`) while the queue
/// is full, then a queue-full error; an immediate queue-closed error
/// once the receiving side is gone. Nothing is ever silently dropped.
fn enqueue(queue: &SyncSender<Job>, job: Job, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    let mut job = job;
    loop {
        match queue.try_send(job) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Disconnected(_)) => {
                return Err(CftError::Coordinator(
                    "request queue closed (batcher gone)".into(),
                ));
            }
            Err(TrySendError::Full(rejected)) => {
                if Instant::now() >= deadline {
                    return Err(CftError::Coordinator(format!(
                        "request queue full ({SUBMIT_QUEUE_DEPTH} pending)"
                    )));
                }
                job = rejected;
                // virtual under a model run: the bounded wait costs no
                // wall-clock time and times out deterministically
                crate::sync::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Embed + vector-search one batch of jobs, then fan work out to the pool.
fn dispatch_batch(
    jobs: Vec<Job>,
    engine: &Arc<dyn Engine>,
    store: &Arc<VectorStore>,
    topk: usize,
    work_tx: &SyncSender<WorkItem>,
) {
    let shape = engine.shape();
    let batch_start = Instant::now();
    let mut jobs = jobs;
    while !jobs.is_empty() {
        let take = jobs.len().min(shape.batch);
        let chunk: Vec<Job> = jobs.drain(..take).collect();

        let mut tokens = vec![0i32; shape.batch * shape.max_tokens];
        for (i, job) in chunk.iter().enumerate() {
            tokens[i * shape.max_tokens..(i + 1) * shape.max_tokens]
                .copy_from_slice(&tokenize_padded(&job.query, shape.max_tokens));
        }
        let hits = engine.embed(&tokens).and_then(|qemb| {
            if store.is_empty() {
                Ok(vec![Vec::new(); chunk.len()])
            } else {
                search_topk(engine.as_ref(), store, &qemb, chunk.len(), topk)
            }
        });
        // the embed_search span runs from batch-dispatch start so it
        // also covers waiting behind earlier chunks of the same batch
        let chunk_done = Instant::now();
        match hits {
            Ok(rows) => {
                for (job, row) in chunk.into_iter().zip(rows) {
                    trace::record(
                        job.trace,
                        Stage::EmbedSearch,
                        take as u32,
                        batch_start,
                        chunk_done.duration_since(batch_start),
                    );
                    let item = WorkItem {
                        job,
                        doc_hits: row.iter().map(|h| h.doc).collect(),
                        dispatched: chunk_done,
                    };
                    if work_tx.send(item).is_err() {
                        return; // workers gone; shutting down
                    }
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for job in chunk {
                    job.resp.deliver(Err(CftError::Runtime(msg.clone())));
                }
            }
        }
    }
}

/// The per-query stage: NER → tree retrieval → context → generation.
#[allow(clippy::too_many_arguments)]
fn serve_one(
    item: &WorkItem,
    engine: &Arc<dyn Engine>,
    forest: &Arc<Forest>,
    ner: &Arc<GazetteerNer>,
    retriever: &Arc<dyn ConcurrentRetriever>,
    store: &Arc<VectorStore>,
    cache: &EmbedCache,
    ctx_cache: &ContextCache,
    levels: usize,
) -> Result<ServeResponse> {
    let traced = item.job.trace.is_sampled();
    let picked = Instant::now();
    if traced {
        trace::record(
            item.job.trace,
            Stage::WorkerWait,
            0,
            item.dispatched,
            picked.duration_since(item.dispatched),
        );
    }
    let query = &item.job.query;
    let entities = ner.recognize(query);
    let ner_done = Instant::now();
    if traced {
        trace::record(
            item.job.trace,
            Stage::Ner,
            entities.len() as u32,
            picked,
            ner_done.duration_since(picked),
        );
    }

    // No retriever-wide lock: each find takes at most a shard read lock,
    // so workers run this stage in parallel.
    let probes_before =
        if traced { retriever.probe_counters() } else { None };
    let rt = Timer::start();
    let mut context = Context::default();
    let mut addrs = Vec::with_capacity(64);
    for e in &entities {
        // memoized contexts short-circuit the filter walk entirely; a
        // miss fills through the token so a write racing this query
        // cannot park pre-write facts in the cache (fill-race guard,
        // `retrieval/context_cache.rs`)
        let (hit, token) = ctx_cache.lookup(e);
        if let Some(ctx) = hit {
            context.merge((*ctx).clone());
            continue;
        }
        addrs.clear();
        retriever.find_concurrent(e, &mut addrs);
        let generated = generate_context(forest, e, &addrs, levels);
        if ctx_cache.enabled() {
            ctx_cache.admit(e, generated.clone(), token);
        }
        context.merge(generated);
    }
    let retrieval_time = rt.elapsed();
    let retrieval_done = Instant::now();
    if traced {
        // arg = cuckoo slots this request probed (process-wide delta;
        // concurrent requests can inflate it, which monitoring accepts)
        let probed = probes_before
            .and_then(|(_, before)| {
                retriever
                    .probe_counters()
                    .map(|(_, after)| after.saturating_sub(before))
            })
            .unwrap_or(0);
        trace::record(
            item.job.trace,
            Stage::Retrieval,
            u32::try_from(probed).unwrap_or(u32::MAX),
            ner_done,
            retrieval_done.duration_since(ner_done),
        );
    }

    let docs_text: Vec<String> = item
        .doc_hits
        .iter()
        .map(|&d| store.doc(d).body.clone())
        .collect();
    let prompt = Prompt::assemble(docs_text, &context, query);
    let generator = Generator::with_cache(engine.as_ref(), cache.clone());
    let answer = generator.generate(query, &context, &prompt)?;
    if traced {
        trace::record(
            item.job.trace,
            Stage::Generate,
            0,
            retrieval_done,
            retrieval_done.elapsed(),
        );
    }

    Ok(ServeResponse {
        answer: answer.text,
        entities,
        fact_count: context.len(),
        docs: item.doc_hits.clone(),
        retrieval_time,
        total_time: item.job.enqueued.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::corpus_from_texts;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};
    use crate::runtime::engine::NativeEngine;

    fn start_coordinator() -> Coordinator {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        Coordinator::start(
            forest,
            docs,
            engine,
            RagConfig::default(),
            CoordinatorConfig { workers: 2, ..Default::default() },
        )
        .unwrap()
    }

    #[test]
    fn serves_single_query() {
        let c = start_coordinator();
        let r = c.query_blocking("where does cardiology sit in the organization").unwrap();
        assert!(r.entities.contains(&"cardiology".to_string()));
        assert!(r.fact_count > 0);
        assert!(r.answer.contains("cardiology"));
        c.shutdown();
    }

    #[test]
    fn serves_concurrent_queries_batched() {
        let c = start_coordinator();
        let queries = [
            "describe the hierarchy around cardiology",
            "where does surgery sit in the organization",
            "what is the parent unit of oncology",
            "list the structure above and below radiology",
            "which units report to pediatrics and who oversees it",
            "describe the hierarchy around pathology",
        ];
        let rxs: Vec<_> =
            queries.iter().map(|q| c.submit(q).expect("submit")).collect();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            assert!(!r.answer.is_empty());
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.requests, 6);
        assert!(snap.batches >= 1);
        assert!(snap.mean_batch_fill >= 1.0);
        c.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let c = start_coordinator();
        let _ = c.query_blocking("describe the hierarchy around cardiology");
        c.shutdown(); // must not hang
    }

    #[test]
    fn stop_works_through_shared_reference() {
        // the TCP layer and the shard router hold Arc<Coordinator>; a
        // backend must be stoppable without unwrapping the Arc
        let c = Arc::new(start_coordinator());
        let c2 = c.clone();
        let _ = c.query_blocking("describe the hierarchy around cardiology");
        c2.stop();
        let err = c.submit("anything").expect_err("stopped must reject");
        assert!(err.to_string().contains("stopped"), "{err}");
        c.stop(); // idempotent
    }

    fn test_job(query: &str) -> Job {
        let (resp, _rx) = crate::sync::mpsc::channel();
        Job {
            query: query.into(),
            enqueued: Instant::now(),
            trace: TraceId::NONE,
            resp: Delivery::Channel(resp),
        }
    }

    #[test]
    fn enqueue_errors_when_queue_closed() {
        let (tx, rx) = sync_channel::<Job>(1);
        drop(rx); // batcher gone
        let err = enqueue(&tx, test_job("q"), Duration::from_millis(50))
            .expect_err("closed queue must error, not drop the job");
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn enqueue_errors_when_queue_stays_full() {
        let (tx, _rx) = sync_channel::<Job>(1);
        enqueue(&tx, test_job("first"), Duration::from_millis(50))
            .expect("first job fits");
        let err = enqueue(&tx, test_job("second"), Duration::from_millis(50))
            .expect_err("full queue must error after the bounded wait");
        assert!(err.to_string().contains("full"), "{err}");
    }

    #[test]
    fn enqueue_succeeds_once_space_frees_up() {
        let (tx, rx) = sync_channel::<Job>(1);
        enqueue(&tx, test_job("first"), Duration::from_millis(50)).unwrap();
        let drainer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let drained = rx.recv().expect("job present");
            drained.query
        });
        // blocks briefly (bounded), then lands once the drainer empties
        // the queue — the explicit-backpressure happy path
        enqueue(&tx, test_job("second"), Duration::from_secs(2))
            .expect("frees up within the deadline");
        assert_eq!(drainer.join().unwrap(), "first");
    }

    #[test]
    fn dynamic_update_validates_and_applies() {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let c = Coordinator::start(
            forest.clone(),
            corpus_from_texts(&ds.documents()),
            engine,
            RagConfig::default(),
            CoordinatorConfig { workers: 2, ..Default::default() },
        )
        .unwrap();

        // out-of-forest addresses are rejected before touching the index
        assert!(c.update_entity("cardiology", 9999, 0).is_err());
        assert!(c.update_entity("cardiology", 0, 9999).is_err());

        // delete a known entity: retrieval for it goes dark, idempotently
        let addr = forest
            .entity_id("cardiology")
            .map(|id| forest.scan_addresses(id)[0])
            .expect("cardiology in the hospital forest");
        let before = c.query_blocking("tell me about cardiology").unwrap();
        assert!(before.fact_count > 0);
        assert!(c.remove_entity("cardiology").unwrap());
        assert!(!c.remove_entity("cardiology").unwrap(), "idempotent");
        let gone = c.query_blocking("tell me about cardiology").unwrap();
        assert_eq!(gone.fact_count, 0, "deleted entity must stop retrieving");

        // re-inserting one of its real occurrences brings it back; a
        // retried identical insert is an idempotent no-op, not a dup
        assert!(c.update_entity("cardiology", addr.tree, addr.node).unwrap());
        assert!(
            !c.update_entity("cardiology", addr.tree, addr.node).unwrap(),
            "retried insert must not duplicate the occurrence"
        );
        let back = c.query_blocking("tell me about cardiology").unwrap();
        assert!(back.fact_count > 0, "re-inserted entity must retrieve");
        c.shutdown();
    }

    #[test]
    fn repartition_dump_and_drop_pass_roundtrip() {
        use crate::rag::config::KeyPartition;

        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let c = Coordinator::start(
            forest.clone(),
            corpus_from_texts(&ds.documents()),
            engine,
            RagConfig::default(),
            CoordinatorConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(c.partition_epoch(), 0, "unpartitioned start is epoch 0");

        // a full index dumps every entity's true address list
        let addrs = c.dump_entity("cardiology");
        let mut want = forest
            .entity_id("cardiology")
            .map(|id| forest.scan_addresses(id))
            .unwrap();
        let mut got = addrs.clone();
        got.sort();
        want.sort();
        assert_eq!(got, want);
        assert!(c.dump_entity("no such entity").is_empty());

        // install a 1-of-2 partition at epoch 3: the epoch is reported,
        // serving is unchanged until the drop pass
        let p = KeyPartition::new(["a:1", "b:2"], 0, 1)
            .unwrap()
            .with_epoch(3);
        c.set_partition(Some(p.clone()), 3).unwrap();
        assert_eq!(c.partition_epoch(), 3);
        let live_before = c.live_index_bytes();
        let dropped = c.drop_disowned().unwrap();
        let disowned = forest
            .interner()
            .iter()
            .filter(|(_, n)| {
                !p.owns(crate::filter::fingerprint::entity_key(n))
            })
            .count();
        assert_eq!(dropped, disowned, "drop pass = exactly the disowned keys");
        if dropped > 0 {
            assert!(c.live_index_bytes() < live_before);
            // a disowned key no longer dumps (and a re-run is a no-op)
            let lost = forest
                .interner()
                .iter()
                .find(|(_, n)| {
                    !p.owns(crate::filter::fingerprint::entity_key(n))
                })
                .map(|(_, n)| n.to_string())
                .unwrap();
            assert!(c.dump_entity(&lost).is_empty(), "{lost}");
        }
        assert_eq!(c.drop_disowned().unwrap(), 0, "idempotent");

        // clearing the partition resets to full-index behavior
        c.set_partition(None, 4).unwrap();
        assert_eq!(c.partition_epoch(), 4);
        assert_eq!(c.drop_disowned().unwrap(), 0);
        c.shutdown();
    }

    #[test]
    fn warm_restart_recovers_acked_ops_and_epoch() {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let dir = std::env::temp_dir()
            .join(format!("cft-coord-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = RagConfig {
            data_dir: Some(dir.clone()),
            ..RagConfig::default()
        };
        let start = || {
            Coordinator::start(
                forest.clone(),
                docs.clone(),
                Arc::new(NativeEngine::new()),
                cfg.clone(),
                CoordinatorConfig { workers: 1, ..Default::default() },
            )
            .unwrap()
        };
        let addr = forest
            .entity_id("cardiology")
            .map(|id| forest.scan_addresses(id)[0])
            .expect("cardiology in the hospital forest");

        // boot 1: ack a delete and an insert, then "crash" (drop
        // without stop — no shutdown snapshot, so boot 2 exercises the
        // log-replay-only path)
        {
            let c = start();
            assert!(c.remove_entity("oncology").unwrap());
            assert!(c.remove_entity("cardiology").unwrap());
            assert!(c
                .update_entity("cardiology", addr.tree, addr.node)
                .unwrap());
            let d = c.durability().expect("persistent backend");
            assert_eq!(d.log_records_appended, 3);
            assert!(d.log_fsyncs >= 3, "fsync_every=1 syncs per ack");
            assert!(!d.snapshot_loaded);
        }

        // boot 2: log replay only — acked delete stays deleted, acked
        // re-insert survives
        {
            let c = start();
            let d = c.durability().unwrap();
            assert_eq!(d.log_replayed, 3);
            assert!(!d.snapshot_loaded);
            assert!(c.dump_entity("oncology").is_empty(), "resurrected");
            assert_eq!(c.dump_entity("cardiology"), vec![addr]);
            // record an epoch, then stop gracefully → final snapshot
            c.set_partition(None, 5).unwrap();
            c.stop();
        }

        // boot 3: snapshot restore (log folded in), recorded epoch wins
        {
            let c = start();
            let d = c.durability().unwrap();
            assert!(d.snapshot_loaded, "shutdown snapshot must load");
            assert_eq!(d.log_replayed, 0, "log was folded into snapshot");
            assert_eq!(c.partition_epoch(), 5, "recorded epoch re-admits");
            assert!(c.dump_entity("oncology").is_empty());
            assert_eq!(c.dump_entity("cardiology"), vec![addr]);
            // on-demand snapshot works and counts
            assert!(c.trigger_snapshot().unwrap() > 0);
            assert_eq!(c.durability().unwrap().snapshots_written, 1);
            c.stop();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn volatile_backend_has_no_durability_surface() {
        let c = start_coordinator();
        assert!(c.durability().is_none());
        let err = c.trigger_snapshot().expect_err("no data dir");
        assert!(err.to_string().contains("data-dir"), "{err}");
        c.shutdown();
    }

    #[test]
    fn start_rejects_invalid_partition_config() {
        use crate::rag::config::KeyPartition;
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 2,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let cfg = RagConfig {
            replication_factor: 1, // contradicts the R=2 partition below
            key_partition: Some(
                KeyPartition::new(["a:1", "b:2"], 0, 2).unwrap(),
            ),
            ..RagConfig::default()
        };
        let err = Coordinator::start(
            forest,
            corpus_from_texts(&ds.documents()),
            engine,
            cfg,
            CoordinatorConfig { workers: 1, ..Default::default() },
        )
        .expect_err("mismatched partition must fail fast");
        assert!(err.to_string().contains("replication"), "{err}");
    }

    #[test]
    fn unknown_entities_still_answered() {
        let c = start_coordinator();
        let r = c.query_blocking("tell me about flux capacitors").unwrap();
        assert_eq!(r.fact_count, 0);
        assert!(r.answer.contains("No hierarchy information"));
        c.shutdown();
    }
}
