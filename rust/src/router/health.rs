//! Per-backend health: passive failure marking on the query path,
//! active probing (the TCP protocol's `\x01stats` control line) with
//! automatic re-admission, all on lock-free atomics so the scatter path
//! can consult health without synchronizing with the prober.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::router::backend::Backend;
use crate::util::log;

/// Health and load observations for one backend. All methods are
/// `&self` and atomic; counters are monitoring-grade (relaxed).
#[derive(Debug)]
pub struct HealthState {
    healthy: AtomicBool,
    consecutive_failures: AtomicU32,
    failure_threshold: u32,
    probes: AtomicU64,
    readmissions: AtomicU64,
    /// Last `requests` gauge read from the backend's `\x01stats` reply
    /// — backend *load*, not just connectivity.
    observed_requests: AtomicU64,
}

impl HealthState {
    /// New state, initially healthy (a backend must fail to be demoted;
    /// starting pessimistic would force every cold start through the
    /// failover path).
    pub fn new(failure_threshold: u32) -> Self {
        HealthState {
            healthy: AtomicBool::new(true),
            consecutive_failures: AtomicU32::new(0),
            failure_threshold: failure_threshold.max(1),
            probes: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            observed_requests: AtomicU64::new(0),
        }
    }

    /// Current serving eligibility.
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }

    /// Record a successful round trip; returns `true` when this
    /// *re-admitted* a backend that was marked down.
    pub fn mark_success(&self) -> bool {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        !self.healthy.swap(true, Ordering::AcqRel)
    }

    /// Record a failed round trip; returns `true` when this crossing of
    /// the failure threshold marked the backend down.
    pub fn mark_failure(&self) -> bool {
        let failures =
            self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if failures >= self.failure_threshold {
            self.healthy.swap(false, Ordering::AcqRel)
        } else {
            false
        }
    }

    /// Record one active probe round (attempted, regardless of outcome).
    pub fn record_probe(&self) {
        self.probes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a re-admission (for the metrics snapshot).
    pub fn record_readmission(&self) {
        self.readmissions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the backend's `requests` gauge from a stats probe.
    pub fn record_load(&self, requests: u64) {
        self.observed_requests.store(requests, Ordering::Relaxed);
    }

    /// Last observed backend request counter (0 before any probe).
    pub fn observed_load(&self) -> u64 {
        self.observed_requests.load(Ordering::Relaxed)
    }

    /// Probes attempted so far.
    pub fn probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    /// Times this backend was re-admitted after being marked down.
    pub fn readmissions(&self) -> u64 {
        self.readmissions.load(Ordering::Relaxed)
    }
}

/// Background prober: every `interval`, one `\x01stats` round trip per
/// backend. Success re-admits a down backend (and refreshes its load
/// gauge); failure demotes it — so a killed backend stops attracting
/// first-attempt traffic within one probe period even with no queries
/// flowing, and rejoins automatically when it comes back.
#[derive(Debug)]
pub struct HealthProber {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl HealthProber {
    /// Start probing `backends`; a zero `interval` disables probing
    /// entirely (no thread — deterministic tests, external checkers).
    pub fn start(backends: Vec<Arc<Backend>>, interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        if interval.is_zero() || backends.is_empty() {
            return HealthProber { stop, thread: None };
        }
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("cft-router-prober".into())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        for b in &backends {
                            // outcome lands in the backend's HealthState;
                            // a failed probe is the demotion signal itself
                            let _ = b.probe();
                        }
                        // sleep in short slices so shutdown is prompt
                        // even with a long probe interval
                        let mut left = interval;
                        while !left.is_zero() && !stop.load(Ordering::Acquire)
                        {
                            let nap = left.min(Duration::from_millis(25));
                            std::thread::sleep(nap);
                            left -= nap;
                        }
                    }
                })
                .expect("spawn health prober")
        };
        HealthProber { stop, thread: Some(thread) }
    }

    /// Stop and join the prober thread (no-op when probing is off).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            if t.join().is_err() {
                log::warn!("health prober panicked");
            }
        }
    }
}

impl Drop for HealthProber {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_and_readmission_transitions() {
        let h = HealthState::new(2);
        assert!(h.is_healthy());
        assert!(!h.mark_failure(), "below threshold: still healthy");
        assert!(h.is_healthy());
        assert!(h.mark_failure(), "threshold crossed: marked down");
        assert!(!h.is_healthy());
        assert!(!h.mark_failure(), "already down: no new transition");
        assert!(h.mark_success(), "success re-admits");
        assert!(h.is_healthy());
        assert!(!h.mark_success(), "already healthy: no transition");
        // one success resets the failure streak
        assert!(!h.mark_failure());
        assert!(h.is_healthy());
    }

    #[test]
    fn load_and_counters() {
        let h = HealthState::new(1);
        assert_eq!(h.observed_load(), 0);
        h.record_load(42);
        h.record_probe();
        h.record_readmission();
        assert_eq!(h.observed_load(), 42);
        assert_eq!(h.probes(), 1);
        assert_eq!(h.readmissions(), 1);
    }

    #[test]
    fn disabled_prober_spawns_nothing_and_shuts_down() {
        let mut p = HealthProber::start(Vec::new(), Duration::ZERO);
        p.shutdown();
        p.shutdown(); // idempotent
    }
}
