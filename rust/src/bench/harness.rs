//! Measurement harness (criterion replacement): warmup + repeated timed
//! runs + summary statistics, plus pretty table printing for the paper
//! reproductions.

use crate::util::stats::{Summary, Timer};

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-repeat wall times in seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Summary stats over the samples.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples)
    }

    /// Mean seconds.
    pub fn mean(&self) -> f64 {
        self.summary().mean
    }
}

/// Run `f` `warmup` times untimed, then `repeats` times timed.
///
/// `f` should perform one full workload pass (the paper repeats each
/// algorithm 100 times and averages; benches pass repeats=… to match).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, repeats: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(repeats);
    for _ in 0..repeats {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    BenchResult { name: name.to_string(), samples }
}

/// Render a results table: column headers + rows of cells.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// Format seconds like the paper's tables (6 decimal places).
pub fn fmt_secs(s: f64) -> String {
    format!("{s:.6}")
}

/// Format a speedup factor.
pub fn fmt_speedup(base: f64, other: f64) -> String {
    if other == 0.0 {
        "inf".to_string()
    } else {
        format!("{:.1}x", base / other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench("noop", 2, 5, || {
            n += 1;
        });
        assert_eq!(r.samples.len(), 5);
        assert_eq!(n, 7, "warmup + repeats");
        assert!(r.mean() >= 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(0.0123456789), "0.012346");
        assert_eq!(fmt_speedup(1.0, 0.1), "10.0x");
        assert_eq!(fmt_speedup(1.0, 0.0), "inf");
    }
}
