//! Rendezvous (highest-random-weight) backend ring: entity-key →
//! backend ownership for the shard router.
//!
//! Every backend gets a stable seed (FNV-1a of its address); a key's
//! owner is the backend with the highest
//! [`rendezvous_score`](crate::filter::fingerprint::rendezvous_score)
//! — the same mix family that picks in-process shards, so the two
//! levels of sharding compose without correlation (see
//! `filter/fingerprint.rs`).
//!
//! Rendezvous hashing gives the minimal-disruption property by
//! construction: removing a backend from consideration only moves the
//! keys that backend owned (the argmax over a subset is unchanged when
//! a non-maximal element is dropped), and the full score ranking *is*
//! the failover order — which is also why elastic membership changes
//! (`router/rebalance.rs`) move only the keys whose serving set
//! actually changed (property-tested below: no gratuitous churn).
//!
//! # Examples
//!
//! ```
//! use cft_rag::filter::fingerprint::entity_key;
//! use cft_rag::router::ring::ShardRing;
//!
//! let ring = ShardRing::new(["10.0.0.1:7171", "10.0.0.2:7171", "10.0.0.3:7171"]);
//! let key = entity_key("cardiology");
//!
//! // the owner is rank 0 of the deterministic failover order
//! let ranked = ring.ranked(key);
//! assert_eq!(ring.owner(key), Some(ranked[0]));
//!
//! // a key's R=2 replica set is the length-2 prefix of that order
//! assert_eq!(ring.replicas(key, 2), &ranked[..2]);
//!
//! // excluding the owner (e.g. it is unhealthy) fails over to rank 1
//! let fallback = ring.owner_where(key, |i| i != ranked[0]);
//! assert_eq!(fallback, Some(ranked[1]));
//! ```

use crate::filter::fingerprint::rendezvous_score;
use crate::util::rng::fnv1a;

/// Ownership ring over the router's backends. Index-stable: backend `i`
/// is always `names[i]`; health is tracked elsewhere and passed in as a
/// predicate, so the ring itself is immutable and lock-free to read.
#[derive(Clone, Debug)]
pub struct ShardRing {
    names: Vec<String>,
    seeds: Vec<u64>,
}

impl ShardRing {
    /// Build over backend addresses (order fixes tie-breaks; duplicate
    /// addresses are tolerated and tie-break by index).
    pub fn new<S: Into<String>>(backends: impl IntoIterator<Item = S>) -> Self {
        let names: Vec<String> = backends.into_iter().map(Into::into).collect();
        let seeds = names.iter().map(|n| fnv1a(n.as_bytes())).collect();
        ShardRing { names, seeds }
    }

    /// Number of backends.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True if the ring fronts no backends.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Address of backend `i`.
    pub fn name(&self, i: usize) -> &str {
        &self.names[i]
    }

    /// Rendezvous score of `key` on backend `i` (test/bench hook).
    pub fn score(&self, key: u64, i: usize) -> u64 {
        rendezvous_score(key, self.seeds[i])
    }

    /// Owner of `key` among the backends where `eligible(i)` holds:
    /// highest score wins, ties broken by lowest index. `None` when no
    /// backend is eligible.
    pub fn owner_where(
        &self,
        key: u64,
        mut eligible: impl FnMut(usize) -> bool,
    ) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for i in 0..self.names.len() {
            if !eligible(i) {
                continue;
            }
            let s = self.score(key, i);
            // strictly-greater keeps the lowest index on score ties
            match best {
                Some((bs, _)) if s <= bs => {}
                _ => best = Some((s, i)),
            }
        }
        best.map(|(_, i)| i)
    }

    /// Owner of `key` over the whole ring.
    pub fn owner(&self, key: u64) -> Option<usize> {
        self.owner_where(key, |_| true)
    }

    /// All backends ranked by descending score for `key` — element 0 is
    /// the owner, the rest is the deterministic failover order.
    pub fn ranked(&self, key: u64) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.names.len()).collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(self.score(key, i)), i));
        order
    }

    /// The key's **replica set**: the first `r` backends of the
    /// [`ranked`](ShardRing::ranked) order (`r` is clamped to `1..=len`).
    /// Element 0 is the owner; the rest are the read replicas / write
    /// fan-out targets of R-way replicated serving.
    ///
    /// Because rendezvous scores are per-(key, backend) and never depend
    /// on the rest of the membership, the replica set inherits minimal
    /// disruption: a backend joining the ring can only *enter* a key's
    /// replica set (evicting the previous rank-R holder) — it never
    /// reorders the survivors. Property-tested below.
    pub fn replicas(&self, key: u64, r: usize) -> Vec<usize> {
        let mut order = self.ranked(key);
        order.truncate(r.max(1));
        // debug/`contracts` builds: a malformed replica set would
        // silently under-replicate every key it serves
        crate::router::contracts::check_replica_set(self.len(), r, &order);
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::fingerprint::entity_key;
    use crate::util::proptest::forall_simple;
    use crate::util::rng::Rng;

    fn ring(n: usize) -> ShardRing {
        ShardRing::new((0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)))
    }

    #[test]
    fn ownership_spreads_across_backends() {
        let r = ring(4);
        let mut counts = [0usize; 4];
        for i in 0..8_000u64 {
            counts[r.owner(fnv1a(&i.to_le_bytes())).unwrap()] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!(
                (1_400..2_600).contains(c),
                "backend {i} owns {c}/8000: {counts:?}"
            );
        }
    }

    #[test]
    fn ranked_head_is_owner_and_covers_all() {
        let r = ring(5);
        for name in ["cardiology", "oncology", "ward 3"] {
            let key = entity_key(name);
            let ranked = r.ranked(key);
            assert_eq!(ranked[0], r.owner(key).unwrap());
            let mut sorted = ranked.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4], "permutation");
        }
    }

    #[test]
    fn owner_where_respects_eligibility() {
        let r = ring(3);
        let key = entity_key("cardiology");
        let owner = r.owner(key).unwrap();
        // excluding the owner yields the next-ranked backend
        let fallback = r.owner_where(key, |i| i != owner).unwrap();
        assert_ne!(fallback, owner);
        assert_eq!(fallback, r.ranked(key)[1]);
        // nothing eligible -> None
        assert_eq!(r.owner_where(key, |_| false), None);
    }

    #[test]
    fn replica_sets_are_ranked_prefixes_and_join_minimally() {
        // Three properties of `replicas(key, r)` (the replication
        // invariants of ISSUE 4):
        //  1. it is exactly the length-r prefix of `ranked(key)`;
        //  2. it never contains duplicates for r <= N;
        //  3. a backend *joining* the ring is disruption-minimal: the
        //     new replica set minus the joined backend is a prefix of
        //     the old replica set — survivors keep their relative
        //     order, and at most the rank-R holder is evicted.
        forall_simple(
            128,
            |rng: &mut Rng| {
                let backends = 2 + rng.range(0, 7); // 2..=8
                let r = 1 + rng.range(0, backends); // 1..=backends
                let keys: Vec<u64> =
                    (0..64).map(|_| rng.next_u64()).collect();
                (backends, r, keys)
            },
            |(backends, r, keys)| {
                let before = ring(*backends);
                let after = ring(*backends + 1); // same names + one joined
                let joined = *backends;
                for &key in keys {
                    let reps = before.replicas(key, *r);
                    if reps.len() != (*r).min(*backends) {
                        return Err(format!(
                            "key {key:#x}: {} replicas for r={r}",
                            reps.len()
                        ));
                    }
                    if reps[..] != before.ranked(key)[..reps.len()] {
                        return Err(format!(
                            "key {key:#x}: replicas {reps:?} not a prefix \
                             of ranked"
                        ));
                    }
                    let mut dedup = reps.clone();
                    dedup.sort_unstable();
                    dedup.dedup();
                    if dedup.len() != reps.len() {
                        return Err(format!(
                            "key {key:#x}: duplicate replicas {reps:?}"
                        ));
                    }
                    let survivors: Vec<usize> = after
                        .replicas(key, *r)
                        .into_iter()
                        .filter(|&i| i != joined)
                        .collect();
                    if survivors[..] != reps[..survivors.len()] {
                        return Err(format!(
                            "key {key:#x}: join reshuffled survivors \
                             {survivors:?} vs old {reps:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn join_moves_only_keys_whose_serving_set_changed() {
        // The elasticity invariant of ISSUE 5 (no gratuitous churn):
        // for a backend joining the ring, the rebalance plan — "stream
        // key K iff its new serving set contains the joiner" — must
        // move exactly the keys whose serving *address* set changed.
        //  1. A key whose new serving set omits the joiner keeps its
        //     serving addresses verbatim (nothing to move, and the
        //     planner skips it).
        //  2. A key whose new serving set includes the joiner changes
        //     by exactly the joiner evicting the old rank-R holder
        //     (or extending the set when the ring was smaller than R)
        //     — survivors keep their relative order.
        // Together: planned keys = changed keys, and each change is
        // one eviction, never a reshuffle. Exercised across both
        // replicated (R >= 1) and full-index (R = 0 → whole ring)
        // serving-set shapes via `rebalance::serving_addrs`.
        use crate::router::rebalance::serving_addrs;

        forall_simple(
            128,
            |rng: &mut Rng| {
                let backends = 2 + rng.range(0, 7); // 2..=8
                let r = rng.range(0, backends + 1); // 0..=backends (0 = full)
                let keys: Vec<u64> =
                    (0..64).map(|_| rng.next_u64()).collect();
                (backends, r, keys)
            },
            |(backends, r, keys)| {
                let before = ring(*backends);
                let after = ring(*backends + 1);
                let joiner_addr = after.name(*backends).to_string();
                for &key in keys {
                    let old = serving_addrs(&before, *r, key);
                    let new = serving_addrs(&after, *r, key);
                    let planned = new.contains(&joiner_addr);
                    if !planned && new != old {
                        return Err(format!(
                            "key {key:#x}: unplanned churn {old:?} -> \
                             {new:?} (joiner not in the new set)"
                        ));
                    }
                    if planned {
                        // survivors = new set minus the joiner; they
                        // must be a prefix-order-preserving subset of
                        // the old set (one eviction at most, no
                        // reshuffle)
                        let survivors: Vec<&String> = new
                            .iter()
                            .filter(|a| **a != joiner_addr)
                            .collect();
                        if survivors.len() + 1 < old.len() {
                            return Err(format!(
                                "key {key:#x}: join evicted {} members \
                                 ({old:?} -> {new:?})",
                                old.len() - survivors.len()
                            ));
                        }
                        let old_refs: Vec<&String> =
                            old.iter().take(survivors.len()).collect();
                        if survivors != old_refs {
                            return Err(format!(
                                "key {key:#x}: join reshuffled \
                                 survivors {survivors:?} vs {old:?}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn replicas_clamp_to_ring_size() {
        let r = ring(3);
        let key = entity_key("cardiology");
        assert_eq!(r.replicas(key, 0), r.replicas(key, 1), "0 acts as 1");
        assert_eq!(r.replicas(key, 99), r.ranked(key), "r > N is whole ring");
    }

    #[test]
    fn minimal_disruption_under_backend_removal() {
        // Property (the routing invariant of ISSUE 3): removing one
        // backend reassigns exactly the keys it owned — every other
        // key keeps its owner. Rendezvous hashing guarantees this;
        // the test guards against regressions to modulo-style hashing.
        forall_simple(
            128,
            |rng: &mut Rng| {
                let backends = 2 + rng.range(0, 7); // 2..=8
                let removed = rng.range(0, backends);
                let keys: Vec<u64> =
                    (0..64).map(|_| rng.next_u64()).collect();
                (backends, removed, keys)
            },
            |(backends, removed, keys)| {
                let r = ring(*backends);
                for &key in keys {
                    let before = r.owner(key).unwrap();
                    let after =
                        r.owner_where(key, |i| i != *removed).unwrap();
                    if before == *removed {
                        if after == *removed {
                            return Err(format!(
                                "key {key:#x} still routed to removed \
                                 backend {removed}"
                            ));
                        }
                    } else if after != before {
                        return Err(format!(
                            "key {key:#x} moved {before} -> {after} though \
                             backend {removed} (not its owner) was removed"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
