//! Pooled TCP connections to one backend.
//!
//! The router keeps a small free list of idle connections per backend
//! so the steady-state query path pays no TCP handshake. The pool is
//! *only* the free list: connecting, IO, and deadlines all live in the
//! outbound reactor ([`crate::reactor::client::NetDriver`]), which
//! checks sockets out of here, runs the nonblocking round trip, and
//! returns them after a fully clean exchange. Per-request deadlines
//! are therefore exact reactor timers covering connect + write + the
//! whole reply — not per-stream kernel socket timeouts set once at
//! connect time, as in the pre-reactor design.
//!
//! The pool makes no liveness promise for idle connections — a backend
//! restart leaves stale sockets behind — so the driver retries
//! idle-connection failures against a fresh connection before the
//! consumer (`router/backend.rs`) counts the backend as unhealthy.
//!
//! # Examples
//!
//! ```
//! use std::net::{TcpListener, TcpStream};
//! use cft_rag::router::pool::ConnPool;
//!
//! // a listener stands in for a backend
//! let listener = TcpListener::bind("127.0.0.1:0").unwrap();
//! let addr = listener.local_addr().unwrap().to_string();
//!
//! let pool = ConnPool::new(addr.clone(), 2); // at most two idle sockets
//! assert!(pool.take_idle().is_none(), "nothing pooled yet");
//! let conn = TcpStream::connect(&addr).unwrap();
//! pool.put_back(conn); // after a clean round trip
//! assert_eq!(pool.idle_count(), 1);
//! assert!(pool.take_idle().is_some(), "steady state skips the handshake");
//! ```

use std::net::TcpStream;

use crate::sync::Mutex;

/// Idle-connection free list for one backend address.
#[derive(Debug)]
pub struct ConnPool {
    addr: String,
    idle: Mutex<Vec<TcpStream>>,
    max_idle: usize,
}

impl ConnPool {
    /// New pool for `addr`, keeping at most `max_idle` idle sockets.
    pub fn new(addr: impl Into<String>, max_idle: usize) -> Self {
        ConnPool {
            addr: addr.into(),
            idle: Mutex::new(Vec::new()),
            max_idle,
        }
    }

    /// The backend address this pool's sockets are connected to (the
    /// driver resolves and dials it).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Pop one idle connection, if any (freshness not guaranteed).
    pub fn take_idle(&self) -> Option<TcpStream> {
        self.idle.lock().unwrap().pop()
    }

    /// Return a connection after a clean round trip (dropped — i.e.
    /// closed — when the pool is already full).
    pub fn put_back(&self, stream: TcpStream) {
        let mut idle = self.idle.lock().unwrap();
        if idle.len() < self.max_idle {
            idle.push(stream);
        }
    }

    /// Drop every idle connection (e.g. after the backend was marked
    /// down, so a recovered backend starts from fresh sockets).
    pub fn clear(&self) {
        self.idle.lock().unwrap().clear();
    }

    /// Idle connections currently pooled.
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn checkin_checkout_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let pool = ConnPool::new(addr.clone(), 2);
        assert_eq!(pool.addr(), addr);
        assert!(pool.take_idle().is_none());
        pool.put_back(TcpStream::connect(&addr).unwrap());
        assert_eq!(pool.idle_count(), 1);
        assert!(pool.take_idle().is_some());
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn pool_caps_idle_and_clears() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let pool = ConnPool::new(addr.clone(), 2);
        for _ in 0..4 {
            pool.put_back(TcpStream::connect(&addr).unwrap());
        }
        assert_eq!(pool.idle_count(), 2, "excess connections dropped");
        pool.clear();
        assert_eq!(pool.idle_count(), 0);
    }
}
