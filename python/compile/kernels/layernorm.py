"""L1 Pallas kernel: fused layer-norm (mean/var/normalize/affine, one pass).

Used as the output head of the embedder (model.embed). Fusing the three
reductions plus the affine into one VMEM-resident pass avoids materializing
mean/var to HBM — the standard fused-layernorm structure.

TPU mapping: a (block_b, D) tile per grid step; D=64 keeps a tile at
block_b=8 to 2 KiB, so the grid only exists to scale to larger batches.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, gamma_ref, beta_ref, out_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)        # [block_b, D]
    gamma = gamma_ref[...].astype(jnp.float32)  # [D]
    beta = beta_ref[...].astype(jnp.float32)    # [D]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centered = x - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    out_ref[...] = centered * inv * gamma + beta


@functools.partial(jax.jit, static_argnames=("block_b", "eps"))
def layer_norm(x, gamma, beta, *, eps=1e-5, block_b=8):
    """Fused layer-norm over the last axis.

    Args:
      x:     [B, D] float.
      gamma: [D] float scale.
      beta:  [D] float shift.

    Returns:
      [B, D] float32.
    """
    b, d = x.shape
    if b < block_b:
        block_b = b
    assert b % block_b == 0, f"B={b} not divisible by block_b={block_b}"
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, gamma, beta)
