//! Prompt assembly (Figure 1's final pre-LLM step): system prompt +
//! retrieved documents + hierarchical context + user query.

use crate::retrieval::context::Context;

/// The system preamble fused into every prompt.
pub const SYSTEM_PROMPT: &str = "You are an assistant answering questions \
about organizational hierarchies. Use ONLY the provided context. State \
each relationship explicitly.";

/// A fully assembled prompt.
#[derive(Clone, Debug)]
pub struct Prompt {
    pub system: String,
    pub documents: Vec<String>,
    pub context: String,
    pub query: String,
}

impl Prompt {
    /// Assemble from pipeline pieces.
    pub fn assemble(documents: Vec<String>, context: &Context, query: &str) -> Prompt {
        Prompt {
            system: SYSTEM_PROMPT.to_string(),
            documents,
            context: context.render(),
            query: query.to_string(),
        }
    }

    /// Render to the flat string an LLM would consume.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("[system]\n");
        out.push_str(&self.system);
        out.push_str("\n\n[documents]\n");
        for (i, d) in self.documents.iter().enumerate() {
            out.push_str(&format!("({i}) {d}\n"));
        }
        out.push_str("\n[hierarchy context]\n");
        out.push_str(&self.context);
        out.push_str("\n[query]\n");
        out.push_str(&self.query);
        out
    }

    /// Approximate token count (whitespace split) for length accounting.
    pub fn approx_tokens(&self) -> usize {
        self.render().split_whitespace().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retrieval::context::{Context, ContextFact, Direction};

    #[test]
    fn renders_all_sections() {
        let ctx = Context {
            facts: vec![ContextFact {
                entity: "icu".into(),
                related: "cardiology".into(),
                direction: Direction::Up,
                tree: 0,
                distance: 1,
            }],
        };
        let p = Prompt::assemble(
            vec!["Mercy hospital history.".into()],
            &ctx,
            "where is the icu",
        );
        let text = p.render();
        assert!(text.contains("[system]"));
        assert!(text.contains("Mercy hospital history."));
        assert!(text.contains("icu is under cardiology"));
        assert!(text.contains("where is the icu"));
        assert!(p.approx_tokens() > 10);
    }
}
