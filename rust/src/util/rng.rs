//! Deterministic pseudo-random number generation.
//!
//! The offline build image has no `rand` crate, so this module owns the
//! substrate: a [SplitMix64](https://prng.di.unimi.it/splitmix64.c) seeder
//! feeding a [Xoshiro256\*\*](https://prng.di.unimi.it/xoshiro256starstar.c)
//! generator, plus the distributions the generators and workloads need
//! (uniform ranges, shuffles, weighted choice, Zipf). Everything is fully
//! deterministic from the seed so every experiment is reproducible.

/// SplitMix64 step: used to expand a single `u64` seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG. Small, fast, and good enough for workload synthesis.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // 128-bit multiply trick; bias negligible for our n, but do one
        // rejection round to keep it exact.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Sample an index proportional to `weights` (all >= 0, sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted() with zero total");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fork an independent stream (for parallel generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

/// Zipf-distributed sampler over `{0, .., n-1}` with exponent `s`.
///
/// Used by the query workload generator: the paper's temperature design
/// exploits *locality* — a few hot entities dominate queries — which is
/// exactly a Zipf access pattern. Sampling is inverse-CDF over the
/// precomputed harmonic weights (O(log n) per draw).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` ranks with exponent `s` (s=0 => uniform).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf over empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in `[0, n)`; rank 0 is the hottest.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Domain size.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the domain is empty (never: constructor asserts).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

/// Stable 64-bit FNV-1a hash of arbitrary bytes — the shared string hash
/// used by tokenizers and fingerprints (deterministic across runs, unlike
/// `std::collections` hashers).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled order changed");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "frac2={frac2}");
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let mut rng = Rng::new(13);
        let z = Zipf::new(1000, 1.1);
        let mut head = 0;
        for _ in 0..10_000 {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // With s=1.1 over 1000 ranks the top-10 should draw >30% of mass.
        assert!(head > 3_000, "head={head}");
    }

    #[test]
    fn zipf_zero_exponent_is_uniformish() {
        let mut rng = Rng::new(17);
        let z = Zipf::new(10, 0.0);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 300.0, "c={c}");
        }
    }

    #[test]
    fn fnv1a_stable_and_spreads() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a(b"hospital"), fnv1a(b"hospitam"));
        assert_eq!(fnv1a(b"entity"), fnv1a(b"entity"));
    }

    #[test]
    fn fork_streams_independent() {
        let mut a = Rng::new(5);
        let mut f = a.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| f.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
