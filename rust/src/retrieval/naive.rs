//! Naive T-RAG (paper §4.1): no filtering — BFS every tree for every
//! entity. O(total forest nodes) per query entity; the baseline whose
//! scaling Table 1/2 show degrading with tree count and query size.

use std::sync::Arc;

use crate::forest::traverse::Bfs;
use crate::forest::{EntityAddress, Forest};
use crate::retrieval::Retriever;

/// The unfiltered baseline retriever.
pub struct NaiveTRag {
    forest: Arc<Forest>,
}

impl NaiveTRag {
    /// Wrap a forest (no index to build).
    pub fn new(forest: Arc<Forest>) -> Self {
        NaiveTRag { forest }
    }
}

impl Retriever for NaiveTRag {
    fn name(&self) -> &'static str {
        "Naive T-RAG"
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        let mut out = Vec::new();
        self.find_into(entity, &mut out);
        out
    }

    fn reindex(&mut self, forest: Arc<Forest>, _new_trees: &[u32]) {
        self.forest = forest; // index-free: nothing else to refresh
    }

    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        let Some(id) = self.forest.entity_id(entity) else {
            return;
        };
        for (t, tree) in self.forest.trees().iter().enumerate() {
            for idx in Bfs::new(tree) {
                if tree.entity(idx) == id {
                    out.push(EntityAddress::new(t as u32, idx));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let a = f.intern("alpha");
        let b = f.intern("beta");
        let mut t0 = Tree::with_root(a);
        t0.add_child(0, b);
        f.add_tree(t0);
        let mut t1 = Tree::with_root(b);
        t1.add_child(0, a);
        f.add_tree(t1);
        Arc::new(f)
    }

    #[test]
    fn finds_all_occurrences() {
        let mut r = NaiveTRag::new(forest());
        let addrs = r.find("beta");
        assert_eq!(addrs.len(), 2);
        assert_eq!(addrs[0].tree, 0);
        assert_eq!(addrs[1], EntityAddress::new(1, 0));
    }

    #[test]
    fn unknown_entity_empty() {
        let mut r = NaiveTRag::new(forest());
        assert!(r.find("gamma").is_empty());
    }

    #[test]
    fn matches_forest_scan() {
        let f = forest();
        let mut r = NaiveTRag::new(f.clone());
        let id = f.entity_id("alpha").unwrap();
        assert_eq!(r.find("alpha"), f.scan_addresses(id));
    }
}
