//! Forest construction from (child, parent) relation tuples — the output
//! of the §2 pre-processing pipeline. One relation group (one document /
//! organization) yields one or more trees: every node without a parent in
//! the group becomes a root.
//!
//! The builder is defensive: it tolerates duplicate edges, multiple
//! parents (first one wins — the relation filter should already have
//! pruned these) and cycles (back-edges are skipped via a visited set),
//! so malformed extraction output degrades gracefully instead of hanging.

use std::collections::{HashMap, HashSet};

use crate::forest::forest::Forest;
use crate::forest::interner::EntityId;
use crate::forest::tree::Tree;

/// Build trees from one relation group, returning the new tree indices.
///
/// `relations` are (child, parent) name pairs, already normalized.
pub fn build_trees(forest: &mut Forest, relations: &[(String, String)]) -> Vec<u32> {
    // Intern every name; record first-parent and children adjacency.
    let mut parent_of: HashMap<EntityId, EntityId> = HashMap::new();
    let mut children_of: HashMap<EntityId, Vec<EntityId>> = HashMap::new();
    let mut seen_edges: HashSet<(EntityId, EntityId)> = HashSet::new();
    let mut order: Vec<EntityId> = Vec::new(); // deterministic iteration
    let mut known: HashSet<EntityId> = HashSet::new();

    for (child, parent) in relations {
        let c = forest.intern(child);
        let p = forest.intern(parent);
        for id in [p, c] {
            if known.insert(id) {
                order.push(id);
            }
        }
        if c == p || !seen_edges.insert((c, p)) {
            continue; // self-loop or duplicate edge
        }
        if parent_of.contains_key(&c) {
            continue; // second parent: first one wins
        }
        parent_of.insert(c, p);
        children_of.entry(p).or_default().push(c);
    }

    // Roots: nodes that never appear as a child.
    let roots: Vec<EntityId> = order
        .iter()
        .copied()
        .filter(|id| !parent_of.contains_key(id))
        .collect();

    let mut out = Vec::new();
    let mut placed: HashSet<EntityId> = HashSet::new();
    for root in roots {
        let mut tree = Tree::with_root(root);
        placed.insert(root);
        // BFS attach children, guarding against cycles.
        let mut queue = vec![(0u32, root)];
        while let Some((node_idx, id)) = queue.pop() {
            if let Some(kids) = children_of.get(&id) {
                for &k in kids {
                    if placed.insert(k) {
                        let ci = tree.add_child(node_idx, k);
                        queue.push((ci, k));
                    }
                }
            }
        }
        out.push(forest.add_tree(tree));
    }

    // Nodes trapped in pure cycles (no root reaches them): emit each
    // unplaced strongly-connected remnant as its own single-node tree so
    // no extracted entity silently vanishes from the knowledge base.
    for id in order {
        if !placed.contains(&id) {
            // break the cycle at this node: attach reachable unplaced nodes
            let mut tree = Tree::with_root(id);
            placed.insert(id);
            let mut queue = vec![(0u32, id)];
            while let Some((node_idx, nid)) = queue.pop() {
                if let Some(kids) = children_of.get(&nid) {
                    for &k in kids {
                        if placed.insert(k) {
                            let ci = tree.add_child(node_idx, k);
                            queue.push((ci, k));
                        }
                    }
                }
            }
            out.push(forest.add_tree(tree));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(c: &str, p: &str) -> (String, String) {
        (c.to_string(), p.to_string())
    }

    #[test]
    fn single_tree_from_relations() {
        let mut f = Forest::new();
        let idxs = build_trees(
            &mut f,
            &[
                rel("cardiology", "hospital"),
                rel("surgery", "hospital"),
                rel("icu", "cardiology"),
            ],
        );
        assert_eq!(idxs.len(), 1);
        let t = f.tree(idxs[0]);
        assert_eq!(t.len(), 4);
        assert_eq!(f.entity_name(t.entity(t.root())), "hospital");
        assert_eq!(t.max_depth(), 2);
    }

    #[test]
    fn multiple_roots_make_multiple_trees() {
        let mut f = Forest::new();
        let idxs = build_trees(
            &mut f,
            &[rel("a", "root1"), rel("b", "root2")],
        );
        assert_eq!(idxs.len(), 2);
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut f = Forest::new();
        let idxs = build_trees(
            &mut f,
            &[rel("a", "r"), rel("a", "r"), rel("a", "r")],
        );
        assert_eq!(f.tree(idxs[0]).len(), 2);
    }

    #[test]
    fn second_parent_ignored() {
        let mut f = Forest::new();
        let idxs = build_trees(
            &mut f,
            &[rel("a", "r1"), rel("a", "r2")],
        );
        // a under r1; r2 becomes its own tree
        assert_eq!(idxs.len(), 2);
        let sizes: Vec<usize> = idxs.iter().map(|&i| f.tree(i).len()).collect();
        assert_eq!(sizes, vec![2, 1]);
    }

    #[test]
    fn self_loop_dropped() {
        let mut f = Forest::new();
        let idxs = build_trees(&mut f, &[rel("x", "x"), rel("y", "x")]);
        assert_eq!(idxs.len(), 1);
        assert_eq!(f.tree(idxs[0]).len(), 2);
    }

    #[test]
    fn cycle_does_not_hang_and_keeps_entities() {
        let mut f = Forest::new();
        let idxs = build_trees(
            &mut f,
            &[rel("a", "b"), rel("b", "a")],
        );
        // pure 2-cycle: emitted as one tree rooted at the first entity seen
        assert_eq!(idxs.len(), 1);
        let total: usize = idxs.iter().map(|&i| f.tree(i).len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn same_entity_across_groups_lands_in_both_trees() {
        let mut f = Forest::new();
        build_trees(&mut f, &[rel("cardiology", "hospital-a")]);
        build_trees(&mut f, &[rel("cardiology", "hospital-b")]);
        let card = f.entity_id("cardiology").unwrap();
        assert_eq!(f.scan_addresses(card).len(), 2);
    }
}
