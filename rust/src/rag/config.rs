//! Pipeline configuration.

use crate::filter::cuckoo::CuckooConfig;

/// Which retrieval algorithm backs the pipeline (paper §4.1–4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Naive T-RAG: BFS every tree.
    Naive,
    /// Bloom Filter T-RAG.
    Bloom,
    /// Improved Bloom Filter T-RAG (skip near-leaf checks).
    Bloom2,
    /// Cuckoo Filter T-RAG (the paper's system).
    Cuckoo,
}

impl Algorithm {
    /// All four, in the paper's table order.
    pub const ALL: [Algorithm; 4] =
        [Algorithm::Naive, Algorithm::Bloom, Algorithm::Bloom2, Algorithm::Cuckoo];

    /// Table label.
    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::Naive => "Naive T-RAG",
            Algorithm::Bloom => "BF T-RAG",
            Algorithm::Bloom2 => "BF2 T-RAG",
            Algorithm::Cuckoo => "CF T-RAG",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_lowercase().as_str() {
            "naive" => Some(Algorithm::Naive),
            "bloom" | "bf" => Some(Algorithm::Bloom),
            "bloom2" | "bf2" => Some(Algorithm::Bloom2),
            "cuckoo" | "cf" => Some(Algorithm::Cuckoo),
            _ => None,
        }
    }
}

/// End-to-end pipeline configuration.
#[derive(Clone, Debug)]
pub struct RagConfig {
    /// Retrieval algorithm.
    pub algorithm: Algorithm,
    /// Hierarchy levels captured up/down in context (paper's n).
    pub context_levels: usize,
    /// Documents fetched by the vector-search stage.
    pub topk_docs: usize,
    /// Bloom baselines: per-node filter FP rate.
    pub bloom_fp_rate: f64,
    /// Cuckoo filter tuning. Of serving interest:
    /// `cuckoo.migration_step_buckets` bounds how long a shard write
    /// lock is held while the filter doubles under load — smaller steps
    /// mean tighter reader tail latency during growth; `0` opts back
    /// into the monolithic single-hold migration (bench comparison arm).
    pub cuckoo: CuckooConfig,
    /// Cuckoo filter shards (rounded up to a power of two). On the
    /// concurrent serving path (`make_concurrent_retriever`), `0` =
    /// auto (one shard per available core). The single-threaded
    /// `make_retriever` has no parallelism to win, so there `0` and `1`
    /// both select the classic unsharded filter (whose probe statistics
    /// the Figure-5 bench reads); only `shards > 1` shards it. Ignored
    /// by the non-Cuckoo baselines.
    pub shards: usize,
}

impl Default for RagConfig {
    fn default() -> Self {
        RagConfig {
            algorithm: Algorithm::Cuckoo,
            context_levels: 3,
            topk_docs: 3,
            bloom_fp_rate: 0.01,
            cuckoo: CuckooConfig::default(),
            shards: 0,
        }
    }
}

impl RagConfig {
    /// Resolve the configured shard count: `0` maps to the number of
    /// available cores (so coordinator read throughput scales with the
    /// worker pool by default), anything else passes through.
    pub fn resolved_shards(&self) -> usize {
        if self.shards == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.shards
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_labels() {
        assert_eq!(Algorithm::parse("cf"), Some(Algorithm::Cuckoo));
        assert_eq!(Algorithm::parse("NAIVE"), Some(Algorithm::Naive));
        assert_eq!(Algorithm::parse("bf2"), Some(Algorithm::Bloom2));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn labels_match_paper() {
        assert_eq!(Algorithm::Cuckoo.label(), "CF T-RAG");
        assert_eq!(Algorithm::ALL.len(), 4);
    }

    #[test]
    fn migration_step_knob_flows_through() {
        use crate::filter::cuckoo::CuckooFilter;
        use crate::filter::fingerprint::entity_key;

        let mut cfg = RagConfig::default();
        assert!(
            cfg.cuckoo.migration_step_buckets > 0,
            "serving config must default to incremental expansion"
        );
        // The knob must change actual filter behavior, not just sit in
        // the struct: with 1-bucket steps a threshold crossing leaves
        // the doubling observably in flight after an insert burst...
        cfg.cuckoo.initial_buckets = 64;
        cfg.cuckoo.migration_step_buckets = 1;
        let mut incremental = CuckooFilter::new(cfg.cuckoo);
        for i in 0..300u64 {
            incremental.insert(entity_key(&format!("knob-{i}")), &[]);
        }
        assert!(
            incremental.migration_pending(),
            "1-bucket steps leave the doubling in flight"
        );
        // ...while 0 (monolithic opt-out) completes inside the insert.
        cfg.cuckoo.migration_step_buckets = 0;
        let mut monolithic = CuckooFilter::new(cfg.cuckoo);
        for i in 0..300u64 {
            monolithic.insert(entity_key(&format!("knob-{i}")), &[]);
        }
        assert!(!monolithic.migration_pending(), "0 = whole-table migration");
    }

    #[test]
    fn shards_resolve() {
        let auto = RagConfig::default();
        assert_eq!(auto.shards, 0, "default is auto");
        assert!(auto.resolved_shards() >= 1);
        let fixed = RagConfig { shards: 8, ..RagConfig::default() };
        assert_eq!(fixed.resolved_shards(), 8);
    }
}
