//! Tiny CSV writer for experiment outputs (bench harness results).

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// In-memory CSV table with a fixed header.
#[derive(Clone, Debug)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// New table with the given column names.
    pub fn new(header: &[&str]) -> Self {
        CsvTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Append a row of displayable values.
    pub fn push<T: std::fmt::Display>(&mut self, cells: &[T]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to CSV text (RFC-4180 quoting where needed).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write to a file, creating parent dirs.
    pub fn write_to(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())
    }
}

fn quote(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic() {
        let mut t = CsvTable::new(&["algo", "time_s"]);
        t.push(&["naive".to_string(), "1.5".to_string()]);
        t.push(&["cf".to_string(), "0.01".to_string()]);
        let out = t.render();
        assert_eq!(out, "algo,time_s\nnaive,1.5\ncf,0.01\n");
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn quotes_commas_and_quotes() {
        let mut t = CsvTable::new(&["a"]);
        t.push(&["x,y".to_string()]);
        t.push(&["he said \"hi\"".to_string()]);
        let out = t.render();
        assert!(out.contains("\"x,y\""));
        assert!(out.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push(&["only-one".to_string()]);
    }
}
