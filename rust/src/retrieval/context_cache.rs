//! Backend-side per-entity context cache — the coordinator half of the
//! hot-entity caching story (`router/cache.rs` is the router half).
//!
//! The per-query retrieval loop in `coordinator/server.rs` walks the
//! filter and traverses the forest once per mentioned entity
//! ([`generate_context`](crate::retrieval::context::generate_context)).
//! Under Zipf mention skew the same hot entities repeat constantly and
//! their trees are immutable between dynamic updates, so the generated
//! [`Context`] can be memoized per entity and reused across queries —
//! including queries for *different* entity sets that share a hot
//! mention, which the router's whole-reply cache cannot serve.
//!
//! The never-stale contract mirrors the reply cache exactly:
//!
//! * **Point invalidation**: every applied `\x01insert` and every
//!   `\x01delete` that removed an entry invalidates that entity's
//!   context *before* the coordinator acks the write.
//! * **Wholesale flush**: `\x01repartition` (a membership epoch
//!   landing on this backend) and the post-rebalance disowned-key drop
//!   pass flush everything — ownership changed under us.
//! * **Fill-race guard**: a worker that looked the entity up, lost the
//!   CPU, and admits a context generated from pre-write state must not
//!   resurrect it after the invalidation. [`ContextCache::lookup`]
//!   returns a [`CtxFillToken`]; [`ContextCache::admit`] declines when
//!   any invalidation of that entity (or a flush) postdates it.
//!
//! Capacity is counted in **entries**, not bytes — contexts are small
//! and uniform (a handful of rendered facts). When full, admission
//! simply declines: under a skewed workload the hot entities are the
//! first to arrive, so a full cache is already holding the right set,
//! and declining is cheaper and simpler than an eviction policy whose
//! wins the router-side sketch already captures.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::retrieval::context::Context;
use crate::sync::Mutex;
use crate::util::rng::fnv1a;

/// Proof of *when* a lookup happened (the invalidation event counter at
/// miss time); carried into [`ContextCache::admit`].
#[derive(Clone, Copy, Debug)]
pub struct CtxFillToken {
    events: u64,
}

/// Counters snapshot: `(hits, misses, invalidations)`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ContextCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub invalidations: u64,
}

#[derive(Debug, Default)]
struct Inner {
    /// entity-key (`fnv1a` of the name) → cached context. The entity
    /// name is stored and compared on hit: a 64-bit collision must
    /// miss, never serve another entity's context.
    entries: HashMap<u64, (String, Arc<Context>)>,
    events: u64,
    flushed_at: u64,
    invalidated: HashMap<u64, u64>,
}

/// Thread-shared per-entity context cache. `capacity == 0` disables it
/// (every method a cheap no-op), which is the library default —
/// `cft-rag serve --context-cache N` turns it on.
#[derive(Debug)]
pub struct ContextCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    invalidations: AtomicU64,
}

impl ContextCache {
    /// New cache holding at most `capacity` entity contexts.
    pub fn new(capacity: usize) -> ContextCache {
        ContextCache {
            capacity,
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    /// Whether this cache can ever hold an entry.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Number of cached contexts.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hit/miss/invalidation counters (reported in the coordinator's
    /// `\x01stats` payload when the cache is enabled).
    pub fn stats(&self) -> ContextCacheStats {
        ContextCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
        }
    }

    /// Look `entity` up; on a miss the caller generates the context
    /// and offers it back through [`admit`](ContextCache::admit) with
    /// the returned token.
    pub fn lookup(&self, entity: &str) -> (Option<Arc<Context>>, CtxFillToken) {
        if !self.enabled() {
            return (None, CtxFillToken { events: 0 });
        }
        let key = fnv1a(entity.as_bytes());
        let inner = self.inner.lock().unwrap();
        let token = CtxFillToken { events: inner.events };
        let hit = inner
            .entries
            .get(&key)
            .filter(|(name, _)| name == entity)
            .map(|(_, ctx)| Arc::clone(ctx));
        drop(inner);
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        (hit, token)
    }

    /// Offer a freshly generated context. Declined when the cache is
    /// full (hot entities arrive first under skew), or when an
    /// invalidation of this entity — or a wholesale flush — postdates
    /// `token` (the fill-race guard). Returns whether it was admitted.
    pub fn admit(&self, entity: &str, ctx: Context, token: CtxFillToken) -> bool {
        if !self.enabled() {
            return false;
        }
        let key = fnv1a(entity.as_bytes());
        let mut inner = self.inner.lock().unwrap();
        if inner.flushed_at > token.events {
            return false;
        }
        if inner.invalidated.get(&key).is_some_and(|&at| at > token.events) {
            return false;
        }
        if inner.entries.len() >= self.capacity && !inner.entries.contains_key(&key)
        {
            return false;
        }
        inner.entries.insert(key, (entity.to_string(), Arc::new(ctx)));
        true
    }

    /// Drop `entity`'s cached context (called by the coordinator after
    /// an applied `\x01insert`/`\x01delete`, before the ack) and arm
    /// the fill-race guard for it. Returns whether an entry existed.
    pub fn invalidate(&self, entity: &str) -> bool {
        if !self.enabled() {
            return false;
        }
        let key = fnv1a(entity.as_bytes());
        let mut inner = self.inner.lock().unwrap();
        inner.events += 1;
        let at = inner.events;
        inner.invalidated.insert(key, at);
        let existed = inner.entries.remove(&key).is_some();
        drop(inner);
        self.invalidations.fetch_add(1, Ordering::Relaxed);
        existed
    }

    /// Drop everything (repartition / disowned-key reclamation) and arm
    /// the fill-race guard globally. Returns entries dropped.
    pub fn flush(&self) -> usize {
        if !self.enabled() {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.events += 1;
        inner.flushed_at = inner.events;
        inner.invalidated.clear();
        let dropped = inner.entries.len();
        inner.entries.clear();
        drop(inner);
        self.invalidations.fetch_add(1, Ordering::Relaxed);
        dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retrieval::context::{ContextFact, Direction};

    fn ctx(entity: &str, related: &str) -> Context {
        Context {
            facts: vec![ContextFact {
                entity: entity.to_string(),
                related: related.to_string(),
                direction: Direction::Up,
                tree: 0,
                distance: 1,
            }],
        }
    }

    #[test]
    fn memoizes_and_counts() {
        let c = ContextCache::new(8);
        let (miss, token) = c.lookup("cardiology");
        assert!(miss.is_none());
        assert!(c.admit("cardiology", ctx("cardiology", "hospital"), token));
        let (hit, _) = c.lookup("cardiology");
        assert_eq!(hit.unwrap().facts[0].related, "hospital");
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn disabled_cache_is_inert() {
        let c = ContextCache::new(0);
        assert!(!c.enabled());
        let (miss, token) = c.lookup("x");
        assert!(miss.is_none());
        assert!(!c.admit("x", ctx("x", "y"), token));
        assert!(!c.invalidate("x"));
        assert_eq!(c.flush(), 0);
        assert_eq!(c.stats(), ContextCacheStats::default());
    }

    #[test]
    fn invalidation_drops_and_guards_racing_fills() {
        let c = ContextCache::new(8);
        let (_, token) = c.lookup("icu");
        assert!(c.admit("icu", ctx("icu", "cardiology"), token));
        // a write lands: the entry goes and the old token is poisoned
        let (_, stale) = c.lookup("icu");
        assert!(c.invalidate("icu"));
        assert!(!c.admit("icu", ctx("icu", "pre-write"), stale));
        assert!(c.lookup("icu").0.is_none(), "stale fill must not land");
        // a token minted after the write admits fine
        let (_, fresh) = c.lookup("icu");
        assert!(c.admit("icu", ctx("icu", "post-write"), fresh));
        assert_eq!(c.lookup("icu").0.unwrap().facts[0].related, "post-write");
    }

    #[test]
    fn flush_guards_everything() {
        let c = ContextCache::new(8);
        let (_, t_a) = c.lookup("a");
        let (_, t_b) = c.lookup("b");
        assert!(c.admit("a", ctx("a", "x"), t_a));
        assert_eq!(c.flush(), 1);
        assert!(!c.admit("b", ctx("b", "y"), t_b), "flush poisons all tokens");
        assert!(c.is_empty());
    }

    #[test]
    fn full_cache_declines_new_entities_but_refreshes_cached_ones() {
        let c = ContextCache::new(2);
        let (_, t) = c.lookup("a");
        assert!(c.admit("a", ctx("a", "1"), t));
        let (_, t) = c.lookup("b");
        assert!(c.admit("b", ctx("b", "1"), t));
        let (_, t) = c.lookup("overflow");
        assert!(!c.admit("overflow", ctx("overflow", "1"), t));
        assert_eq!(c.len(), 2);
        // an already-cached entity may be refreshed in place
        let (_, t) = c.lookup("a");
        assert!(c.admit("a", ctx("a", "2"), t));
        assert_eq!(c.lookup("a").0.unwrap().facts[0].related, "2");
    }
}
