//! Router-level metrics, in the same shape as `coordinator/metrics.rs`:
//! a cheap mutex-guarded sink, cloneable across threads, snapshotted on
//! demand. Per-backend latency uses the shared [`LatencyHistogram`].

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Snapshot of one backend's counters at an instant.
#[derive(Clone, Debug)]
pub struct BackendMetricsSnapshot {
    pub addr: String,
    /// Health at snapshot time (from the backend's [`HealthState`]).
    ///
    /// [`HealthState`]: crate::router::health::HealthState
    pub healthy: bool,
    pub requests: u64,
    pub failures: u64,
    pub latency_mean_s: f64,
    pub latency_p99_s: f64,
}

/// Snapshot of the router's counters at an instant.
#[derive(Clone, Debug)]
pub struct RouterMetricsSnapshot {
    /// Queries answered (one per `Router::query`, merged or not).
    pub requests: u64,
    /// Queries that could not produce an `ok` reply at all.
    pub failures: u64,
    /// Queries fanned out to more than one backend.
    pub fanouts: u64,
    /// Sub-requests served by a backend other than the key's owner.
    pub failovers: u64,
    /// Merged replies missing at least one owner's portion.
    pub degraded: u64,
    pub backends: Vec<BackendMetricsSnapshot>,
}

impl RouterMetricsSnapshot {
    /// Queries per second over an elapsed window.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed.as_secs_f64()
        }
    }

    /// JSON form (the router front door's `\x01stats` payload).
    pub fn to_json(&self) -> Json {
        let backends = self
            .backends
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("addr", Json::Str(b.addr.clone())),
                    ("healthy", Json::Bool(b.healthy)),
                    ("requests", Json::Num(b.requests as f64)),
                    ("failures", Json::Num(b.failures as f64)),
                    ("latency_mean_s", Json::Num(b.latency_mean_s)),
                    ("latency_p99_s", Json::Num(b.latency_p99_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("fanouts", Json::Num(self.fanouts as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("backends", Json::Arr(backends)),
        ])
    }
}

#[derive(Debug, Default)]
struct BackendInner {
    requests: u64,
    failures: u64,
    latency: LatencyHistogram,
}

#[derive(Debug)]
struct Inner {
    requests: u64,
    failures: u64,
    fanouts: u64,
    failovers: u64,
    degraded: u64,
    backends: Vec<BackendInner>,
}

/// Thread-shared router metrics sink.
#[derive(Clone, Debug)]
pub struct RouterMetrics {
    inner: Arc<Mutex<Inner>>,
}

impl RouterMetrics {
    /// New sink for `nbackends` backends.
    pub fn new(nbackends: usize) -> Self {
        RouterMetrics {
            inner: Arc::new(Mutex::new(Inner {
                requests: 0,
                failures: 0,
                fanouts: 0,
                failovers: 0,
                degraded: 0,
                backends: (0..nbackends)
                    .map(|_| BackendInner::default())
                    .collect(),
            })),
        }
    }

    /// Record one completed `Router::query` (ok or not).
    pub fn record_query(&self, ok: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        if !ok {
            m.failures += 1;
        }
    }

    /// Record a multi-backend fanned-out query.
    pub fn record_fanout(&self) {
        self.inner.lock().unwrap().fanouts += 1;
    }

    /// Record a sub-request served off-owner.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    /// Record a merged reply with a missing portion.
    pub fn record_degraded(&self) {
        self.inner.lock().unwrap().degraded += 1;
    }

    /// Record one backend round trip.
    pub fn record_backend(&self, idx: usize, ok: bool, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        let b = &mut m.backends[idx];
        b.requests += 1;
        if !ok {
            b.failures += 1;
        }
        b.latency.record(latency.as_secs_f64());
    }

    /// Snapshot against backend identities: `info[i]` is backend `i`'s
    /// `(addr, healthy-now)` — health lives with the backends, not in
    /// this sink, so the caller (the router) joins the two.
    pub fn snapshot(&self, info: &[(String, bool)]) -> RouterMetricsSnapshot {
        let m = self.inner.lock().unwrap();
        assert_eq!(m.backends.len(), info.len(), "backend count mismatch");
        RouterMetricsSnapshot {
            requests: m.requests,
            failures: m.failures,
            fanouts: m.fanouts,
            failovers: m.failovers,
            degraded: m.degraded,
            backends: m
                .backends
                .iter()
                .zip(info)
                .map(|(b, (addr, healthy))| BackendMetricsSnapshot {
                    addr: addr.clone(),
                    healthy: *healthy,
                    requests: b.requests,
                    failures: b.failures,
                    latency_mean_s: b.latency.mean(),
                    latency_p99_s: b.latency.quantile(0.99),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots_per_backend() {
        let m = RouterMetrics::new(2);
        m.record_query(true);
        m.record_query(false);
        m.record_fanout();
        m.record_failover();
        m.record_degraded();
        m.record_backend(0, true, Duration::from_millis(2));
        m.record_backend(1, false, Duration::from_millis(4));
        let info = vec![("a:1".to_string(), true), ("b:2".to_string(), false)];
        let s = m.snapshot(&info);
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 1);
        assert_eq!(s.fanouts, 1);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.backends[0].requests, 1);
        assert_eq!(s.backends[0].failures, 0);
        assert!(s.backends[0].healthy);
        assert_eq!(s.backends[1].failures, 1);
        assert!(!s.backends[1].healthy);
        assert!(s.backends[1].latency_mean_s > 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let m = RouterMetrics::new(1);
        m.record_query(true);
        m.record_backend(0, true, Duration::from_micros(500));
        let s = m.snapshot(&[("x:1".to_string(), true)]);
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        let backends = back.get("backends").unwrap().as_arr().unwrap();
        assert_eq!(backends[0].get("addr").and_then(Json::as_str), Some("x:1"));
        assert_eq!(backends[0].get("healthy"), Some(&Json::Bool(true)));
    }

    #[test]
    fn throughput_math() {
        let m = RouterMetrics::new(0);
        for _ in 0..50 {
            m.record_query(true);
        }
        let s = m.snapshot(&[]);
        assert!((s.throughput(Duration::from_secs(5)) - 10.0).abs() < 1e-9);
    }
}
