//! The cooperative scheduler behind `--features modelcheck`.
//!
//! One *virtual thread* (vthread) runs at a time: every vthread is a
//! real OS thread, but all of them block on a single master
//! mutex/condvar pair and only the thread whose id equals
//! `State::active` makes progress. The shim primitives in `crate::sync`
//! call [`Shared::yield_point`] before every acquire/load/store/send,
//! which is where the scheduler may preempt — so a whole schedule is a
//! deterministic function of the seed, and a failing interleaving can
//! be replayed exactly by re-running that seed.
//!
//! Scheduling policy is PCT-style (Burckhardt et al., "A Randomized
//! Scheduler with Probabilistic Guarantees of Finding Bugs"): each
//! vthread gets a random priority at spawn, the highest-priority
//! runnable vthread always runs, and at `preemption_depth` randomly
//! chosen step indices the running vthread is demoted below every
//! priority handed out so far. Blocking (locks, channels, joins) is
//! modeled logically: a vthread that cannot proceed parks itself and
//! the scheduler picks the next runnable one; when *nothing* is
//! runnable the scheduler either advances virtual time to the earliest
//! sleep/timeout deadline or — if no deadline exists — declares a
//! deadlock and reports every vthread's parked state.

use std::any::Any;
use std::cell::{Cell, RefCell};
use std::cmp::Reverse;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Once};
use std::time::Duration;

use crate::util::rng::Rng;

use super::Config;

/// What a finished vthread left behind (its closure's boxed return
/// value, or the panic payload).
pub(crate) type ThreadResult =
    std::thread::Result<Box<dyn Any + Send + 'static>>;

/// Resource id for pure sleeps: nothing ever wakes it, only virtual
/// time. Real resources use heap addresses (never this small).
pub(crate) const RES_SLEEP: usize = 0;
/// Resource the controller thread parks on while waiting for every
/// spawned vthread to finish; woken on each vthread exit.
pub(crate) const RES_ALL_DONE: usize = 1;
/// Join waits use `RES_JOIN_BASE + vtid` — still far below any valid
/// heap address, so they cannot collide with address-derived ids.
const RES_JOIN_BASE: usize = 0x10;

fn res_join(vtid: usize) -> usize {
    RES_JOIN_BASE + vtid
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Runnable,
    Blocked,
    Finished,
}

struct VThread {
    status: Status,
    /// Meaningful only while `status == Blocked`.
    resource: usize,
    /// Virtual-time deadline (ns) after which a blocked vthread becomes
    /// runnable again even without a wake (sleeps, `recv_timeout`).
    deadline: Option<u128>,
    /// Human label for deadlock reports ("mutex", "channel-recv", ...).
    waiting_on: &'static str,
    priority: u64,
    name: String,
    result: Option<ThreadResult>,
}

pub(crate) struct State {
    rng: Rng,
    threads: Vec<VThread>,
    active: usize,
    steps: u64,
    max_steps: u64,
    /// Sorted step indices at which the running vthread is demoted.
    change_points: Vec<u64>,
    /// Decreasing counter for demoted priorities: always below every
    /// initial priority (which start at `PRIORITY_FLOOR`).
    next_demotion: u64,
    now_ns: u128,
    failure: Option<String>,
}

/// Initial priorities live in `[FLOOR, FLOOR + 2^32)`; demotions count
/// down from `FLOOR - 1`, so a demoted vthread ranks below everyone.
const PRIORITY_FLOOR: u64 = 1 << 32;

impl State {
    fn new(cfg: &Config, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut change_points: Vec<u64> = (0..cfg.preemption_depth)
            .map(|_| 1 + rng.below(cfg.change_window.max(1)))
            .collect();
        change_points.sort_unstable();
        change_points.dedup();
        State {
            rng,
            threads: Vec::new(),
            active: 0,
            steps: 0,
            max_steps: cfg.max_steps,
            change_points,
            next_demotion: PRIORITY_FLOOR - 1,
            now_ns: 0,
            failure: None,
        }
    }

    fn draw_priority(&mut self) -> u64 {
        PRIORITY_FLOOR + (self.rng.next_u64() >> 32)
    }

    fn register(&mut self, name: String) -> usize {
        let vtid = self.threads.len();
        let priority = self.draw_priority();
        self.threads.push(VThread {
            status: Status::Runnable,
            resource: RES_SLEEP,
            deadline: None,
            waiting_on: "",
            priority,
            name,
            result: None,
        });
        vtid
    }
}

/// Master scheduler state shared by every vthread of one schedule run.
pub(crate) struct Shared {
    state: Mutex<State>,
    cv: Condvar,
    os_handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// The seed this schedule runs under (for failure messages).
    pub(crate) seed: u64,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Shared>, usize)>> =
        const { RefCell::new(None) };
    /// Set right before an abort panic so the quiet hook suppresses the
    /// (expected, uninformative) "schedule aborted" unwind spam.
    static QUIET_PANIC: Cell<bool> = const { Cell::new(false) };
}

/// The scheduler handle + vthread id of the calling thread, when it is
/// part of a model run. The shim primitives branch on this: `None`
/// means "behave exactly like std".
pub(crate) fn managed() -> Option<(Arc<Shared>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

static INSTALL_QUIET_HOOK: Once = Once::new();

/// Install (once, process-wide) a panic hook that suppresses output for
/// our own schedule-abort panics — keyed on a thread-local flag, so
/// genuine assertion failures in other tests keep printing normally.
fn install_quiet_hook() {
    INSTALL_QUIET_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !QUIET_PANIC.with(Cell::get) {
                prev(info);
            }
        }));
    });
}

/// Unwind out of arbitrary user code when the schedule has failed
/// elsewhere; caught by the vthread wrapper (or `run`).
fn abort_schedule() -> ! {
    QUIET_PANIC.with(|q| q.set(true));
    panic!("modelcheck: schedule aborted");
}

fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Shared {
    fn lock_state(&self) -> MutexGuard<'_, State> {
        // A vthread that panics between yield points never holds this
        // mutex, but be tolerant anyway: the state stays consistent.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// One scheduling step: charge a step, maybe demote (PCT change
    /// point), hand the CPU to the highest-priority runnable vthread,
    /// and wait until this vthread is scheduled again.
    pub(crate) fn yield_point(&self, vtid: usize) {
        let mut st = self.lock_state();
        if st.failure.is_some() {
            drop(st);
            abort_schedule();
        }
        st.steps += 1;
        if st.steps >= st.max_steps {
            st.failure = Some(format!(
                "exceeded max_steps={} — livelock, or raise \
                 Config::max_steps",
                st.max_steps
            ));
            self.cv.notify_all();
            drop(st);
            abort_schedule();
        }
        if st.change_points.binary_search(&st.steps).is_ok() {
            let demoted = st.next_demotion;
            st.next_demotion -= 1;
            st.threads[vtid].priority = demoted;
        }
        self.reschedule(&mut st);
        self.wait_for_turn(st, vtid);
    }

    /// Park this vthread on `resource` (optionally with a virtual-time
    /// deadline) and run someone else. Returns when rescheduled — the
    /// caller re-checks its condition in a loop, condvar-style.
    pub(crate) fn block(
        &self,
        vtid: usize,
        resource: usize,
        waiting_on: &'static str,
        timeout: Option<Duration>,
    ) {
        let mut st = self.lock_state();
        if st.failure.is_some() {
            drop(st);
            abort_schedule();
        }
        let deadline = timeout.map(|d| st.now_ns + d.as_nanos());
        let t = &mut st.threads[vtid];
        t.status = Status::Blocked;
        t.resource = resource;
        t.deadline = deadline;
        t.waiting_on = waiting_on;
        self.reschedule(&mut st);
        self.wait_for_turn(st, vtid);
    }

    /// Mark every vthread parked on `resource` runnable. The caller
    /// keeps the CPU until its next yield point (wakes are not
    /// preemption points themselves — the yield before the *next* sync
    /// op is).
    pub(crate) fn wake(&self, resource: usize) {
        if resource == RES_SLEEP {
            return;
        }
        let mut st = self.lock_state();
        for t in &mut st.threads {
            if t.status == Status::Blocked && t.resource == resource {
                t.status = Status::Runnable;
                t.deadline = None;
            }
        }
    }

    /// Current virtual time (ns since the schedule started).
    pub(crate) fn now_ns(&self) -> u128 {
        self.lock_state().now_ns
    }

    /// Pick the next vthread. Called with the state lock held, by the
    /// thread that currently owns the CPU.
    fn reschedule(&self, st: &mut State) {
        let next = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Runnable)
            .max_by_key(|(i, t)| (t.priority, Reverse(*i)))
            .map(|(i, _)| i);
        match next {
            Some(i) => st.active = i,
            None => self.no_runnable(st),
        }
        self.cv.notify_all();
    }

    /// Nothing is runnable: advance virtual time to the earliest
    /// deadline, or — with every vthread parked indefinitely — declare
    /// a deadlock.
    fn no_runnable(&self, st: &mut State) {
        let earliest = st
            .threads
            .iter()
            .filter(|t| t.status == Status::Blocked)
            .filter_map(|t| t.deadline)
            .min();
        if let Some(deadline) = earliest {
            st.now_ns = st.now_ns.max(deadline);
            let now = st.now_ns;
            for t in &mut st.threads {
                if t.status == Status::Blocked
                    && t.deadline.is_some_and(|d| d <= now)
                {
                    t.status = Status::Runnable;
                    t.deadline = None;
                }
            }
            self.reschedule(st);
            return;
        }
        if st.threads.iter().all(|t| t.status == Status::Finished) {
            // Schedule over; `run` notices on its own.
            return;
        }
        let mut lines = vec![format!(
            "deadlock: every virtual thread is parked (step {}, seed {})",
            st.steps, self.seed
        )];
        for (i, t) in st.threads.iter().enumerate() {
            lines.push(match t.status {
                Status::Blocked => format!(
                    "  vthread {i} '{}': blocked on {} (resource {:#x})",
                    t.name, t.waiting_on, t.resource
                ),
                Status::Finished => {
                    format!("  vthread {i} '{}': finished", t.name)
                }
                Status::Runnable => {
                    format!("  vthread {i} '{}': runnable (?)", t.name)
                }
            });
        }
        st.failure = Some(lines.join("\n"));
    }

    /// Block until this vthread owns the CPU (or the schedule failed,
    /// in which case unwind).
    fn wait_for_turn(&self, mut st: MutexGuard<'_, State>, vtid: usize) {
        loop {
            if st.failure.is_some() {
                drop(st);
                abort_schedule();
            }
            if st.active == vtid {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Spawn a vthread running `body`; it first waits to be scheduled,
    /// so the spawner keeps the CPU. Returns the new vthread id.
    pub(crate) fn spawn_vthread(
        self: &Arc<Self>,
        name: Option<String>,
        body: Box<dyn FnOnce() -> Box<dyn Any + Send + 'static> + Send>,
    ) -> usize {
        let vtid = {
            let mut st = self.lock_state();
            let n = st.threads.len();
            st.register(name.unwrap_or_else(|| format!("vthread-{n}")))
        };
        let shared = Arc::clone(self);
        let handle = std::thread::Builder::new()
            .name(format!("modelcheck-v{vtid}"))
            .spawn(move || {
                CURRENT.with(|c| {
                    *c.borrow_mut() = Some((Arc::clone(&shared), vtid));
                });
                let result = catch_unwind(AssertUnwindSafe(|| {
                    let st = shared.lock_state();
                    shared.wait_for_turn(st, vtid);
                    body()
                }));
                shared.finish_vthread(vtid, result);
                CURRENT.with(|c| *c.borrow_mut() = None);
            })
            .expect("spawn modelcheck vthread");
        self.os_handles
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(handle);
        vtid
    }

    fn finish_vthread(&self, vtid: usize, result: ThreadResult) {
        let mut st = self.lock_state();
        if let Err(payload) = &result {
            if st.failure.is_none() {
                st.failure = Some(format!(
                    "vthread {vtid} '{}' panicked: {}",
                    st.threads[vtid].name,
                    panic_message(payload.as_ref())
                ));
            }
        }
        st.threads[vtid].status = Status::Finished;
        st.threads[vtid].result = Some(result);
        let join_res = res_join(vtid);
        for t in &mut st.threads {
            if t.status == Status::Blocked
                && (t.resource == join_res || t.resource == RES_ALL_DONE)
            {
                t.status = Status::Runnable;
                t.deadline = None;
            }
        }
        if st.failure.is_some() {
            self.cv.notify_all();
            return;
        }
        self.reschedule(&mut st);
    }

    /// Wait for `target` to finish and take its result (`me` is the
    /// calling vthread).
    pub(crate) fn join_vthread(
        &self,
        me: usize,
        target: usize,
    ) -> ThreadResult {
        loop {
            self.yield_point(me);
            {
                let mut st = self.lock_state();
                if st.threads[target].status == Status::Finished {
                    return st.threads[target]
                        .result
                        .take()
                        .expect("vthread result already taken");
                }
            }
            self.block(me, res_join(target), "join", None);
        }
    }

    /// Has every vthread other than the controller (vtid 0) finished?
    fn workers_done(&self) -> bool {
        self.lock_state()
            .threads
            .iter()
            .skip(1)
            .all(|t| t.status == Status::Finished)
    }
}

/// Execute one schedule of `body` under `seed`. Returns the failure
/// report (deadlock, panic, livelock) or `Ok(())`.
pub(crate) fn run(
    cfg: &Config,
    seed: u64,
    body: &dyn Fn(),
) -> Result<(), String> {
    install_quiet_hook();
    let shared = Arc::new(Shared {
        state: Mutex::new(State::new(cfg, seed)),
        cv: Condvar::new(),
        os_handles: Mutex::new(Vec::new()),
        seed,
    });
    {
        let mut st = shared.lock_state();
        let vtid = st.register("main".to_string());
        debug_assert_eq!(vtid, 0);
        st.active = 0;
    }
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&shared), 0)));
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        body();
        // Drain detached vthreads: the schedule is only over when every
        // spawned vthread has finished (or everything deadlocked).
        while !shared.workers_done() {
            shared.block(0, RES_ALL_DONE, "all-done", None);
        }
    }));
    CURRENT.with(|c| *c.borrow_mut() = None);
    QUIET_PANIC.with(|q| q.set(false));
    let failure = {
        let mut st = shared.lock_state();
        if let Err(payload) = &outcome {
            if st.failure.is_none() {
                st.failure = Some(format!(
                    "main schedule thread panicked: {}",
                    panic_message(payload.as_ref())
                ));
            }
        }
        st.threads[0].status = Status::Finished;
        let f = st.failure.clone();
        if f.is_some() {
            // Unpark everyone so they observe the failure and unwind.
            for t in &mut st.threads {
                if t.status == Status::Blocked {
                    t.status = Status::Runnable;
                }
            }
            st.active = usize::MAX;
            shared.cv.notify_all();
        }
        f
    };
    let handles = std::mem::take(
        &mut *shared
            .os_handles
            .lock()
            .unwrap_or_else(|e| e.into_inner()),
    );
    for h in handles {
        let _ = h.join();
    }
    match failure {
        Some(msg) => Err(msg),
        None => Ok(()),
    }
}
