//! Dynamic batching: group incoming requests up to the artifact batch
//! size, waiting at most a deadline for stragglers — the standard
//! serving trade-off between device efficiency (full batches for the
//! fixed-shape artifacts) and tail latency.

use crate::sync::mpsc::{Receiver, RecvTimeoutError};
use crate::sync::time::Instant;
use std::time::Duration;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch (the artifact batch size).
    pub max_batch: usize,
    /// Maximum time to hold the first request while waiting for more.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Outcome of one collect call.
pub enum BatchOutcome<T> {
    /// A (possibly partial) batch.
    Batch {
        /// The collected items, submit order preserved.
        items: Vec<T>,
        /// When the first item arrived and opened the batch window —
        /// the boundary the tracer uses to split a request's
        /// `submit_wait` (queued behind earlier batches) from its
        /// `batch_wait` (holding for stragglers).
        opened: Instant,
    },
    /// The channel closed and no items remain.
    Closed,
}

/// Block for the next batch: wait indefinitely for the first item, then
/// fill up to `policy.max_batch` within `policy.max_wait`.
pub fn collect_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> BatchOutcome<T> {
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return BatchOutcome::Closed,
    };
    let opened = Instant::now();
    let mut batch = vec![first];
    let deadline = opened + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    BatchOutcome::Batch { items: batch, opened }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::mpsc::channel;
    use crate::sync::thread;

    #[test]
    fn fills_to_max_when_items_ready() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        match collect_batch(&rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) }) {
            BatchOutcome::Batch { items, .. } => {
                assert_eq!(items, (0..8).collect::<Vec<_>>())
            }
            BatchOutcome::Closed => panic!("closed"),
        }
        // leftovers stay queued
        match collect_batch(&rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) }) {
            BatchOutcome::Batch { items, .. } => assert_eq!(items, vec![8, 9]),
            BatchOutcome::Closed => panic!("closed"),
        }
    }

    #[test]
    fn partial_batch_after_deadline() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let t = Instant::now();
        match collect_batch(&rx, BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) }) {
            BatchOutcome::Batch { items, opened } => {
                assert_eq!(items, vec![1]);
                assert!(t.elapsed() >= Duration::from_millis(9));
                // the window opened at the first recv, before the
                // straggler deadline expired
                assert!(opened.elapsed() >= Duration::from_millis(9));
            }
            BatchOutcome::Closed => panic!("closed"),
        }
    }

    #[test]
    fn closed_channel_reports_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(matches!(
            collect_batch(&rx, BatchPolicy::default()),
            BatchOutcome::Closed
        ));
    }

    #[test]
    fn stragglers_join_within_window() {
        let (tx, rx) = channel();
        tx.send(0).unwrap();
        let tx2 = tx.clone();
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(3));
            tx2.send(1).unwrap();
        });
        match collect_batch(&rx, BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(40) }) {
            BatchOutcome::Batch { items, .. } => {
                assert_eq!(items.len(), 2, "straggler joined")
            }
            BatchOutcome::Closed => panic!("closed"),
        }
    }
}
