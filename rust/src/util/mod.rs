//! Foundation substrates the offline image forced us to own: deterministic
//! RNG + distributions, timing/statistics, CLI flag parsing, JSON, CSV and
//! a mini property-testing harness.

pub mod cli;
pub mod csv;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod wait;
