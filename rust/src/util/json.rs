//! Minimal JSON reader/writer (offline replacement for `serde_json`).
//!
//! The repo needs JSON in two places: writing experiment results and
//! reading `artifacts/manifest.json`. This module implements a small
//! value model, an escaping writer, and a recursive-descent parser —
//! enough for both, with strict error reporting.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (rounds exact floats).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::Str("cuckoo".into())),
            ("buckets", Json::Num(1024.0)),
            ("nested", Json::Arr(vec![Json::Num(1.0), Json::Bool(true), Json::Null])),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "version": 1,
          "embed_dim": 64,
          "artifacts": {
            "embed": {"file": "embed.hlo.txt",
                      "inputs": [{"shape": [8, 32], "dtype": "int32"}]}
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("embed_dim").unwrap().as_usize(), Some(64));
        let inputs = v
            .get("artifacts").unwrap()
            .get("embed").unwrap()
            .get("inputs").unwrap()
            .as_arr().unwrap();
        assert_eq!(
            inputs[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(32)
        );
    }

    #[test]
    fn escapes_strings() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn negative_and_float_numbers() {
        let v = Json::parse("[-3.5, 1e3, 0]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-3.5));
        assert_eq!(a[1].as_f64(), Some(1000.0));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
