//! Relationship filtering — paper §2.3.
//!
//! After extraction the edge set may violate tree-ness. Four repairs, in
//! the paper's order:
//!
//! 1. **Transitive relations**: if "A→B", "B→C" and the shortcut "A→C"
//!    all exist, remove the distant relation A→C (transitive reduction).
//! 2. **Cycle relations**: if a cycle exists, keep only the closest
//!    relationship — we break cycles by dropping the *latest-extracted*
//!    edge in the cycle (extraction order approximates textual proximity,
//!    so earlier = closer).
//! 3. **Self-pointing edges**: A→A removed.
//! 4. **Duplicate edges**: repeated (A, B) pruned to one.

use std::collections::{HashMap, HashSet};

/// (child, parent) edge list in extraction order.
pub type Edges = Vec<(String, String)>;

/// Apply all four §2.3 repairs. Order: self-edges, duplicates, cycles,
/// transitive reduction (reduction last, so it sees a DAG).
pub fn filter_relations(edges: &Edges) -> Edges {
    let mut out: Edges = Vec::new();
    let mut seen: HashSet<(String, String)> = HashSet::new();

    // 3 + 4: drop self-edges and duplicates, preserving order.
    for (c, p) in edges {
        if c == p {
            continue;
        }
        let key = (c.clone(), p.clone());
        if seen.insert(key) {
            out.push((c.clone(), p.clone()));
        }
    }

    // 2: break cycles. Insert edges one at a time: adding child→parent
    // closes a cycle iff the child is already reachable walking upward
    // from the parent. Later edges lose (extraction order ≈ proximity).
    let mut kept: Edges = Vec::new();
    let mut parents: HashMap<&str, Vec<&str>> = HashMap::new();
    for (c, p) in &out {
        if is_ancestor(&parents, p, c) {
            continue; // edge would close a cycle: drop the later relation
        }
        parents.entry(c.as_str()).or_default().push(p.as_str());
        kept.push((c.clone(), p.clone()));
    }

    // 1: transitive reduction — remove A→C if a longer path A ⇒ C exists
    // through the remaining edges.
    let mut reduced: Edges = Vec::new();
    for (i, (c, p)) in kept.iter().enumerate() {
        // Build ancestor map excluding this edge.
        let mut without: HashMap<&str, Vec<&str>> = HashMap::new();
        for (j, (c2, p2)) in kept.iter().enumerate() {
            if i != j {
                without.entry(c2.as_str()).or_default().push(p2.as_str());
            }
        }
        if is_ancestor(&without, c, p) {
            // p still reachable from c without the direct edge => distant
            continue;
        }
        reduced.push((c.clone(), p.clone()));
    }
    reduced
}

/// Is `target` reachable from `start` following child→parent edges?
fn is_ancestor(
    parents: &HashMap<&str, Vec<&str>>,
    start: &str,
    target: &str,
) -> bool {
    if start == target {
        return true;
    }
    let mut stack: Vec<&str> = vec![start];
    let mut visited: HashSet<&str> = HashSet::new();
    while let Some(n) = stack.pop() {
        if !visited.insert(n) {
            continue;
        }
        if let Some(ps) = parents.get(n) {
            for &p in ps {
                if p == target {
                    return true;
                }
                stack.push(p);
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(c: &str, p: &str) -> (String, String) {
        (c.to_string(), p.to_string())
    }

    #[test]
    fn removes_self_edges() {
        let out = filter_relations(&vec![e("a", "a"), e("a", "b")]);
        assert_eq!(out, vec![e("a", "b")]);
    }

    #[test]
    fn removes_duplicates() {
        let out = filter_relations(&vec![e("a", "b"), e("a", "b"), e("a", "b")]);
        assert_eq!(out, vec![e("a", "b")]);
    }

    #[test]
    fn breaks_two_cycle_keeping_earlier() {
        let out = filter_relations(&vec![e("a", "b"), e("b", "a")]);
        assert_eq!(out, vec![e("a", "b")]);
    }

    #[test]
    fn breaks_long_cycle() {
        let out = filter_relations(&vec![e("a", "b"), e("b", "c"), e("c", "a")]);
        assert_eq!(out, vec![e("a", "b"), e("b", "c")]);
    }

    #[test]
    fn transitive_reduction_drops_shortcut() {
        // paper's example: A→B, B→C, A→C  =>  drop A→C
        let out = filter_relations(&vec![e("a", "b"), e("b", "c"), e("a", "c")]);
        assert_eq!(out, vec![e("a", "b"), e("b", "c")]);
    }

    #[test]
    fn keeps_legitimate_dag_edges() {
        // siblings under one parent: nothing removed
        let input = vec![e("x", "r"), e("y", "r"), e("z", "x")];
        assert_eq!(filter_relations(&input), input);
    }

    #[test]
    fn deep_transitive_chain() {
        // a→b→c→d plus shortcut a→d: shortcut removed
        let out = filter_relations(&vec![
            e("a", "b"),
            e("b", "c"),
            e("c", "d"),
            e("a", "d"),
        ]);
        assert_eq!(out.len(), 3);
        assert!(!out.contains(&e("a", "d")));
    }

    #[test]
    fn empty_input() {
        assert!(filter_relations(&Vec::new()).is_empty());
    }

    #[test]
    fn combined_mess() {
        let out = filter_relations(&vec![
            e("icu", "icu"),               // self
            e("icu", "cardiology"),
            e("icu", "cardiology"),        // dup
            e("cardiology", "hospital"),
            e("icu", "hospital"),          // transitive shortcut
            e("hospital", "icu"),          // would close a cycle
        ]);
        assert_eq!(
            out,
            vec![e("icu", "cardiology"), e("cardiology", "hospital")]
        );
    }
}
