//! Cuckoo Filter T-RAG — the paper's system (§4.2). At build time every
//! entity's full address list is packed into a block linked list and
//! indexed by the improved Cuckoo Filter; at query time one O(1) filter
//! lookup replaces the forest traversal entirely. Temperatures are bumped
//! on hit and buckets re-sorted in [`Retriever::maintain`] (§3.1).

use std::sync::Arc;

use crate::filter::cuckoo::{CuckooConfig, CuckooFilter};
use crate::filter::fingerprint::entity_key;
use crate::forest::{EntityAddress, Forest};
use crate::retrieval::Retriever;

/// The Cuckoo-Filter-indexed retriever.
pub struct CuckooTRag {
    forest: Arc<Forest>,
    cf: CuckooFilter,
}

impl CuckooTRag {
    /// Index a forest with the paper's default filter parameters.
    pub fn new(forest: Arc<Forest>) -> Self {
        Self::with_config(forest, CuckooConfig::default())
    }

    /// Index with custom filter parameters (ablations).
    pub fn with_config(forest: Arc<Forest>, cfg: CuckooConfig) -> Self {
        let mut cf = CuckooFilter::new(cfg);
        // One forest pass builds every entity's address list, then each
        // list is inserted behind its fingerprint.
        let table = forest.address_table();
        for (id, addrs) in table {
            let key = entity_key(forest.entity_name(id));
            cf.insert(key, &addrs);
        }
        CuckooTRag { forest, cf }
    }

    /// Access the underlying filter (benches/inspection).
    pub fn filter(&self) -> &CuckooFilter {
        &self.cf
    }

    /// Mutable access (benches that need to reconfigure).
    pub fn filter_mut(&mut self) -> &mut CuckooFilter {
        &mut self.cf
    }

    /// The forest this retriever indexes.
    pub fn forest(&self) -> &Arc<Forest> {
        &self.forest
    }

    /// Dynamic update: register a newly added occurrence of an entity
    /// (inserts the entity if unknown).
    pub fn add_occurrence(&mut self, entity: &str, addr: EntityAddress) {
        let key = entity_key(entity);
        if !self.cf.push_address(key, addr) {
            self.cf.insert(key, &[addr]);
        }
    }

    /// Dynamic update: remove an entity entirely (paper Algorithm 2).
    pub fn remove_entity(&mut self, entity: &str) -> bool {
        self.cf.delete(entity_key(entity))
    }
}

impl Retriever for CuckooTRag {
    fn name(&self) -> &'static str {
        "CF T-RAG"
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        match self.cf.lookup(entity_key(entity)) {
            Some(hit) => self.cf.addresses(hit),
            None => Vec::new(),
        }
    }

    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        if let Some(hit) = self.cf.lookup(entity_key(entity)) {
            out.extend(self.cf.addresses_iter(hit));
        }
    }

    fn maintain(&mut self) {
        self.cf.maintain();
    }

    fn reindex(&mut self, forest: Arc<Forest>, new_trees: &[u32]) {
        // Incremental (the paper's dynamic-update story): only the new
        // trees' addresses are inserted/appended; the existing filter
        // state — including temperatures — is untouched.
        for &t in new_trees {
            let tree = forest.tree(t);
            for idx in tree.indices() {
                let name = forest.entity_name(tree.entity(idx));
                let key = entity_key(name);
                let addr = EntityAddress::new(t, idx);
                if !self.cf.push_address(key, addr) {
                    self.cf.insert(key, &[addr]);
                }
            }
        }
        self.forest = forest;
    }

    fn index_bytes(&self) -> usize {
        self.cf.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let a = f.intern("alpha");
        let b = f.intern("beta");
        let c = f.intern("gamma");
        let mut t0 = Tree::with_root(a);
        t0.add_child(0, b);
        t0.add_child(0, c);
        f.add_tree(t0);
        let mut t1 = Tree::with_root(b);
        t1.add_child(0, a);
        f.add_tree(t1);
        Arc::new(f)
    }

    #[test]
    fn agrees_with_scan() {
        let f = forest();
        let mut r = CuckooTRag::new(f.clone());
        for name in ["alpha", "beta", "gamma", "missing"] {
            let mut got = r.find(name);
            got.sort();
            let mut want = f
                .entity_id(name)
                .map(|id| f.scan_addresses(id))
                .unwrap_or_default();
            want.sort();
            assert_eq!(got, want, "{name}");
        }
    }

    #[test]
    fn temperatures_rise_and_sorting_runs() {
        let f = forest();
        let mut r = CuckooTRag::new(f);
        for _ in 0..5 {
            r.find("alpha");
        }
        r.maintain();
        let key = entity_key("alpha");
        assert_eq!(r.filter().temperature(key), Some(5));
    }

    #[test]
    fn dynamic_add_and_remove() {
        let f = forest();
        let mut r = CuckooTRag::new(f);
        r.add_occurrence("delta", EntityAddress::new(5, 0));
        assert_eq!(r.find("delta").len(), 1);
        r.add_occurrence("delta", EntityAddress::new(6, 3));
        assert_eq!(r.find("delta").len(), 2);
        assert!(r.remove_entity("delta"));
        assert!(r.find("delta").is_empty());
    }

    #[test]
    fn index_memory_reported() {
        let r = CuckooTRag::new(forest());
        assert!(r.index_bytes() > 0);
    }
}
