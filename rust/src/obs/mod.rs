//! Observability plane: unified metrics registry and distributed
//! request tracing, dependency-free.
//!
//! Three pillars, one module (see `docs/OBSERVABILITY.md` for the
//! operator-facing catalog and runbooks):
//!
//! * [`registry`] — a process-wide vocabulary of named counters,
//!   gauges and log-linear histograms. Both front doors' `\x01stats`
//!   payloads are built on it, and the `\x01metrics` control line
//!   renders the whole registry as Prometheus text exposition so one
//!   scraper covers the fleet.
//! * [`trace`] — request tracing across the serving stack. A trace id
//!   is minted at whichever front door a request enters (router or
//!   coordinator) and propagated to backends with an optional
//!   `\x01t=<hex>` line prefix that old peers simply reject per
//!   unknown-control rules, so a fleet upgrades incrementally. Spans
//!   (queue waits, batch formation, retrieval, per-backend exchanges,
//!   merge) land in per-thread lock-free ring buffers and are exported
//!   as JSON via the `\x01trace` control line; slow queries are also
//!   logged through [`crate::util::log`] as structured lines.
//! * Filter internals — the cuckoo hot path exposes relaxed-atomic
//!   telemetry (`crate::filter::FilterTelemetry`) that the coordinator
//!   surfaces under `\x01stats` and `\x01metrics`; the per-request
//!   probe count rides on retrieval spans.
//!
//! Everything here uses [`crate::sync`] primitives, so the registry is
//! exercisable under the deterministic model-check scheduler like the
//! rest of the concurrency core.

pub mod registry;
pub mod trace;

pub use registry::{Counter, Gauge, Histogram, Registry};
pub use trace::{Sampler, SpanRec, Stage, TraceId};
