//! Property-based tests for the improved Cuckoo Filter: random operation
//! sequences checked against a HashMap reference model, plus structural
//! invariants (no false negatives, expansion preserves state, maintain
//! never loses entries).

use std::collections::HashMap;

use cft_rag::filter::cuckoo::{CuckooConfig, CuckooFilter};
use cft_rag::filter::fingerprint::entity_key;
use cft_rag::forest::EntityAddress;
use cft_rag::util::proptest::{forall, forall_simple, shrink_vec, Config};
use cft_rag::util::rng::Rng;

/// A random filter operation.
#[derive(Clone, Debug)]
enum Op {
    Insert(u16, u8),
    Delete(u16),
    Lookup(u16),
    PushAddr(u16),
    Maintain,
}

fn gen_ops(rng: &mut Rng, max_len: usize) -> Vec<Op> {
    let n = rng.range(1, max_len + 1);
    (0..n)
        .map(|_| {
            let id = rng.below(200) as u16;
            match rng.below(10) {
                0..=3 => Op::Insert(id, rng.below(6) as u8),
                4..=5 => Op::Delete(id),
                6..=7 => Op::Lookup(id),
                8 => Op::PushAddr(id),
                _ => Op::Maintain,
            }
        })
        .collect()
}

fn key_of(id: u16) -> u64 {
    entity_key(&format!("prop-entity-{id}"))
}

fn addrs_of(id: u16, n: u8) -> Vec<EntityAddress> {
    (0..n as u32)
        .map(|i| EntityAddress::new(id as u32, i))
        .collect()
}

/// Execute ops against the filter and a HashMap model; compare after
/// every step. Exact-match operations (insert/delete/push) must agree
/// perfectly; lookups may additionally hit on fingerprint collisions
/// (false positives), so the model only demands no false *negatives*.
fn check_sequence(ops: &[Op]) -> Result<(), String> {
    let mut cf = CuckooFilter::new(CuckooConfig {
        initial_buckets: 8, // tiny: forces evictions + expansions
        ..CuckooConfig::default()
    });
    let mut model: HashMap<u16, Vec<EntityAddress>> = HashMap::new();

    for (step, op) in ops.iter().enumerate() {
        match op {
            Op::Insert(id, n) => {
                let a = addrs_of(*id, *n);
                let inserted = cf.insert(key_of(*id), &a);
                let expected = !model.contains_key(id);
                if inserted != expected {
                    return Err(format!(
                        "step {step}: insert({id}) returned {inserted}, model says {expected}"
                    ));
                }
                if inserted {
                    model.insert(*id, a);
                }
            }
            Op::Delete(id) => {
                let deleted = cf.delete(key_of(*id));
                let expected = model.remove(id).is_some();
                if deleted != expected {
                    return Err(format!(
                        "step {step}: delete({id}) returned {deleted}, model says {expected}"
                    ));
                }
            }
            Op::Lookup(id) => {
                let hit = cf.lookup(key_of(*id));
                match model.get(id) {
                    Some(addrs) => {
                        let got = hit
                            .map(|h| cf.addresses(h))
                            .unwrap_or_default();
                        if &got != addrs {
                            return Err(format!(
                                "step {step}: lookup({id}) wrong addresses: {got:?} vs {addrs:?}"
                            ));
                        }
                    }
                    None => { /* false positives allowed */ }
                }
            }
            Op::PushAddr(id) => {
                let pushed =
                    cf.push_address(key_of(*id), EntityAddress::new(999, *id as u32));
                let expected = model.contains_key(id);
                if pushed != expected {
                    return Err(format!(
                        "step {step}: push({id}) returned {pushed}, model says {expected}"
                    ));
                }
                if pushed {
                    model
                        .get_mut(id)
                        .unwrap()
                        .push(EntityAddress::new(999, *id as u32));
                }
            }
            Op::Maintain => cf.maintain(),
        }
        if cf.len() != model.len() {
            return Err(format!(
                "step {step}: len {} != model {}",
                cf.len(),
                model.len()
            ));
        }
    }

    // Final sweep: every model entry retrievable with exact addresses.
    for (id, addrs) in &model {
        match cf.lookup(key_of(*id)) {
            None => return Err(format!("final: false negative for {id}")),
            Some(h) => {
                let got = cf.addresses(h);
                if &got != addrs {
                    return Err(format!("final: {id} addresses {got:?} != {addrs:?}"));
                }
            }
        }
    }
    Ok(())
}

#[test]
fn random_op_sequences_match_model() {
    forall(
        Config { cases: 150, ..Config::default() },
        |rng| gen_ops(rng, 400),
        |ops| check_sequence(ops),
        |ops| shrink_vec(ops),
    );
}

#[test]
fn mass_insert_never_false_negative() {
    forall_simple(
        30,
        |rng| {
            let n = rng.range(1, 4000);
            let seed = rng.next_u64();
            (n, seed)
        },
        |&(n, seed)| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 16,
                seed,
                ..CuckooConfig::default()
            });
            for i in 0..n {
                let k = entity_key(&format!("k{seed}-{i}"));
                if !cf.insert(k, &[]) {
                    return Err(format!("insert {i}/{n} failed"));
                }
            }
            for i in 0..n {
                let k = entity_key(&format!("k{seed}-{i}"));
                if !cf.contains(k) {
                    return Err(format!("false negative at {i}/{n}"));
                }
            }
            if cf.load_factor() > 1.0 {
                return Err("load factor > 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn maintain_preserves_membership_under_heat() {
    forall_simple(
        30,
        |rng| {
            let ids: Vec<u16> = (0..rng.range(2, 60)).map(|_| rng.below(500) as u16).collect();
            let hot: Vec<u16> = (0..rng.range(1, 20)).map(|_| rng.below(500) as u16).collect();
            (ids, hot)
        },
        |(ids, hot)| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 4,
                ..CuckooConfig::default()
            });
            let mut inserted = Vec::new();
            for &id in ids {
                if cf.insert(key_of(id), &addrs_of(id, 2)) {
                    inserted.push(id);
                }
            }
            for &h in hot {
                cf.lookup(key_of(h));
            }
            cf.maintain();
            for &id in &inserted {
                let Some(hit) = cf.lookup(key_of(id)) else {
                    return Err(format!("{id} lost after maintain"));
                };
                if cf.addresses(hit) != addrs_of(id, 2) {
                    return Err(format!("{id} addresses corrupted"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn expansion_scales_power_of_two() {
    forall_simple(
        20,
        |rng| rng.range(1, 5000),
        |&n| {
            let mut cf = CuckooFilter::new(CuckooConfig {
                initial_buckets: 32,
                ..CuckooConfig::default()
            });
            for i in 0..n {
                cf.insert(entity_key(&format!("e{i}")), &[]);
            }
            if !cf.buckets().is_power_of_two() {
                return Err(format!("buckets {} not a power of two", cf.buckets()));
            }
            // load must respect the threshold after growth
            if n > 64 && cf.load_factor() > 0.95 {
                return Err(format!("load factor {} too high", cf.load_factor()));
            }
            Ok(())
        },
    );
}
