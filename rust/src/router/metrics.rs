//! Router-level metrics, in the same shape as `coordinator/metrics.rs`:
//! a cheap mutex-guarded sink, cloneable across threads, snapshotted on
//! demand. Per-backend latency uses the shared [`LatencyHistogram`].
//! Ring membership is elastic (`router/rebalance.rs`), so the
//! per-backend slots grow on join and are remapped on drain, and the
//! snapshot carries the serving ring's membership epoch plus the
//! rebalance counters (`joins`/`drains`/keys streamed/keys dropped/
//! dual writes). `docs/OPERATIONS.md` explains what to do when each
//! counter moves.
//!
//! # Examples
//!
//! ```
//! use std::time::Duration;
//! use cft_rag::router::metrics::RouterMetrics;
//!
//! let m = RouterMetrics::new(2);
//! m.record_query(true);
//! m.record_backend(0, true, Duration::from_millis(2));
//! let info = vec![("a:1".to_string(), true), ("b:2".to_string(), true)];
//! let snap = m.snapshot(&info, 0);
//! assert_eq!(snap.requests, 1);
//! assert_eq!(snap.ring_epoch, 0);
//! assert_eq!(snap.backends[0].requests, 1);
//! // the \x01stats payload is this snapshot as one JSON object
//! assert!(snap.to_json().to_string().contains("\"ring_epoch\""));
//! ```

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Snapshot of one backend's counters at an instant.
#[derive(Clone, Debug)]
pub struct BackendMetricsSnapshot {
    pub addr: String,
    /// Health at snapshot time (from the backend's [`HealthState`]).
    ///
    /// [`HealthState`]: crate::router::health::HealthState
    pub healthy: bool,
    pub requests: u64,
    pub failures: u64,
    pub latency_mean_s: f64,
    pub latency_p99_s: f64,
}

/// Snapshot of the router's counters at an instant.
#[derive(Clone, Debug)]
pub struct RouterMetricsSnapshot {
    /// Queries answered (one per `Router::query`, merged or not).
    pub requests: u64,
    /// Queries that could not produce an `ok` reply at all.
    pub failures: u64,
    /// Queries fanned out to more than one backend.
    pub fanouts: u64,
    /// Sub-requests served by a backend other than the key's owner.
    pub failovers: u64,
    /// Replicated-mode sub-requests served by a non-owner replica
    /// *without* any candidate failing first — the least-loaded load
    /// balancer's choice, not a rescue.
    pub replica_hits: u64,
    /// Merged replies missing at least one portion.
    pub degraded: u64,
    /// Broadcast writes (`\x01insert`/`\x01delete` fan-outs).
    pub write_fanouts: u64,
    /// Broadcast writes that missed their ack quorum.
    pub quorum_fails: u64,
    /// Backends rebalanced into the serving ring (`\x01join`).
    pub joins: u64,
    /// Backends rebalanced out of the serving ring (`\x01drain`).
    pub drains: u64,
    /// Entity keys streamed during warm-up/handoff rebalances.
    pub rebalanced_keys: u64,
    /// Disowned keys reclaimed by post-rebalance drop passes.
    pub dropped_keys: u64,
    /// Writes additionally applied to the incoming epoch's replica set
    /// while a rebalance was in flight (mid-rebalance consistency).
    pub dual_writes: u64,
    /// Backend exchanges cut off by their end-to-end request deadline
    /// on the outbound reactor. Stamped by `Router::snapshot` from the
    /// [`NetDriver`](crate::reactor::client::NetDriver) counter — the
    /// sink itself always reports 0 here.
    pub deadlines_expired: u64,
    /// The serving ring's membership epoch at snapshot time.
    pub ring_epoch: u64,
    pub backends: Vec<BackendMetricsSnapshot>,
}

impl RouterMetricsSnapshot {
    /// Queries per second over an elapsed window.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed.as_secs_f64()
        }
    }

    /// JSON form (the router front door's `\x01stats` payload).
    pub fn to_json(&self) -> Json {
        let backends = self
            .backends
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("addr", Json::Str(b.addr.clone())),
                    ("healthy", Json::Bool(b.healthy)),
                    ("requests", Json::Num(b.requests as f64)),
                    ("failures", Json::Num(b.failures as f64)),
                    ("latency_mean_s", Json::Num(b.latency_mean_s)),
                    ("latency_p99_s", Json::Num(b.latency_p99_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("fanouts", Json::Num(self.fanouts as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("replica_hits", Json::Num(self.replica_hits as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("write_fanouts", Json::Num(self.write_fanouts as f64)),
            ("quorum_fails", Json::Num(self.quorum_fails as f64)),
            ("joins", Json::Num(self.joins as f64)),
            ("drains", Json::Num(self.drains as f64)),
            ("rebalanced_keys", Json::Num(self.rebalanced_keys as f64)),
            ("dropped_keys", Json::Num(self.dropped_keys as f64)),
            ("dual_writes", Json::Num(self.dual_writes as f64)),
            (
                "deadlines_expired",
                Json::Num(self.deadlines_expired as f64),
            ),
            ("ring_epoch", Json::Num(self.ring_epoch as f64)),
            ("backends", Json::Arr(backends)),
        ])
    }
}

#[derive(Debug, Default)]
struct BackendInner {
    requests: u64,
    failures: u64,
    latency: LatencyHistogram,
}

#[derive(Debug)]
struct Inner {
    requests: u64,
    failures: u64,
    fanouts: u64,
    failovers: u64,
    replica_hits: u64,
    degraded: u64,
    write_fanouts: u64,
    quorum_fails: u64,
    joins: u64,
    drains: u64,
    rebalanced_keys: u64,
    dropped_keys: u64,
    dual_writes: u64,
    backends: Vec<BackendInner>,
}

/// Thread-shared router metrics sink.
#[derive(Clone, Debug)]
pub struct RouterMetrics {
    inner: Arc<Mutex<Inner>>,
}

impl RouterMetrics {
    /// New sink for `nbackends` backends.
    pub fn new(nbackends: usize) -> Self {
        RouterMetrics {
            inner: Arc::new(Mutex::new(Inner {
                requests: 0,
                failures: 0,
                fanouts: 0,
                failovers: 0,
                replica_hits: 0,
                degraded: 0,
                write_fanouts: 0,
                quorum_fails: 0,
                joins: 0,
                drains: 0,
                rebalanced_keys: 0,
                dropped_keys: 0,
                dual_writes: 0,
                backends: (0..nbackends)
                    .map(|_| BackendInner::default())
                    .collect(),
            })),
        }
    }

    /// Record one completed `Router::query` (ok or not).
    pub fn record_query(&self, ok: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        if !ok {
            m.failures += 1;
        }
    }

    /// Record a multi-backend fanned-out query.
    pub fn record_fanout(&self) {
        self.inner.lock().unwrap().fanouts += 1;
    }

    /// Record a sub-request served off-owner.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    /// Record a sub-request served by a non-owner replica by load
    /// choice (replicated mode, nothing failed first).
    pub fn record_replica_hit(&self) {
        self.inner.lock().unwrap().replica_hits += 1;
    }

    /// Record a merged reply with a missing portion.
    pub fn record_degraded(&self) {
        self.inner.lock().unwrap().degraded += 1;
    }

    /// Record one broadcast write fan-out.
    pub fn record_write_fanout(&self) {
        self.inner.lock().unwrap().write_fanouts += 1;
    }

    /// Record a broadcast write that missed its ack quorum.
    pub fn record_quorum_fail(&self) {
        self.inner.lock().unwrap().quorum_fails += 1;
    }

    /// Record a completed `\x01join` rebalance: `keys` streamed to the
    /// warmed joiner.
    pub fn record_join(&self, keys: u64) {
        let mut m = self.inner.lock().unwrap();
        m.joins += 1;
        m.rebalanced_keys += keys;
    }

    /// Record a completed `\x01drain` rebalance: `keys` handed off to
    /// their next-ranked owners.
    pub fn record_drain(&self, keys: u64) {
        let mut m = self.inner.lock().unwrap();
        m.drains += 1;
        m.rebalanced_keys += keys;
    }

    /// Record disowned keys reclaimed by a post-rebalance drop pass.
    pub fn record_dropped_keys(&self, keys: u64) {
        self.inner.lock().unwrap().dropped_keys += keys;
    }

    /// Record a write dual-applied to the incoming epoch's replica set
    /// while a rebalance was in flight.
    pub fn record_dual_write(&self) {
        self.inner.lock().unwrap().dual_writes += 1;
    }

    /// Grow the per-backend slots to `n` (a backend joined the ring;
    /// indexes are append-only on join, so existing slots keep their
    /// history).
    pub fn ensure_backends(&self, n: usize) {
        let mut m = self.inner.lock().unwrap();
        while m.backends.len() < n {
            m.backends.push(BackendInner::default());
        }
    }

    /// Remove the per-backend slot `idx` (a backend drained out of the
    /// ring; later slots shift down, matching the new address list).
    ///
    /// Known smear: queries in flight across the swap still hold the
    /// previous membership snapshot and report with *old* indices, so
    /// for that instant their samples land one slot off (or, past the
    /// end, are dropped). The counters are monitoring-grade; a
    /// handful of cross-attributed samples per drain is accepted
    /// rather than tagging every sample with a membership generation.
    pub fn remove_backend(&self, idx: usize) {
        let mut m = self.inner.lock().unwrap();
        if idx < m.backends.len() {
            m.backends.remove(idx);
        }
    }

    /// Record one backend round trip. `idx` beyond the current slot
    /// count is ignored — a query thread holding the pre-drain
    /// membership snapshot may report against a removed slot; dropping
    /// (or, one slot lower, smearing — see
    /// [`remove_backend`](RouterMetrics::remove_backend)) that
    /// monitoring-grade sample beats panicking the query path.
    pub fn record_backend(&self, idx: usize, ok: bool, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        let Some(b) = m.backends.get_mut(idx) else { return };
        b.requests += 1;
        if !ok {
            b.failures += 1;
        }
        b.latency.record(latency.as_secs_f64());
    }

    /// Snapshot against backend identities: `info[i]` is backend `i`'s
    /// `(addr, healthy-now)` — health lives with the backends, not in
    /// this sink, so the caller (the router) joins the two —
    /// and `ring_epoch` is the serving ring's membership epoch. The
    /// zip is tolerant of a transient length mismatch (membership can
    /// change between reading the ring and locking the sink): only the
    /// common prefix is reported.
    pub fn snapshot(
        &self,
        info: &[(String, bool)],
        ring_epoch: u64,
    ) -> RouterMetricsSnapshot {
        let m = self.inner.lock().unwrap();
        RouterMetricsSnapshot {
            requests: m.requests,
            failures: m.failures,
            fanouts: m.fanouts,
            failovers: m.failovers,
            replica_hits: m.replica_hits,
            degraded: m.degraded,
            write_fanouts: m.write_fanouts,
            quorum_fails: m.quorum_fails,
            joins: m.joins,
            drains: m.drains,
            rebalanced_keys: m.rebalanced_keys,
            dropped_keys: m.dropped_keys,
            dual_writes: m.dual_writes,
            deadlines_expired: 0,
            ring_epoch,
            backends: m
                .backends
                .iter()
                .zip(info)
                .map(|(b, (addr, healthy))| BackendMetricsSnapshot {
                    addr: addr.clone(),
                    healthy: *healthy,
                    requests: b.requests,
                    failures: b.failures,
                    latency_mean_s: b.latency.mean(),
                    latency_p99_s: b.latency.quantile(0.99),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots_per_backend() {
        let m = RouterMetrics::new(2);
        m.record_query(true);
        m.record_query(false);
        m.record_fanout();
        m.record_failover();
        m.record_replica_hit();
        m.record_replica_hit();
        m.record_degraded();
        m.record_write_fanout();
        m.record_quorum_fail();
        m.record_join(12);
        m.record_drain(5);
        m.record_dropped_keys(9);
        m.record_dual_write();
        m.record_backend(0, true, Duration::from_millis(2));
        m.record_backend(1, false, Duration::from_millis(4));
        let info = vec![("a:1".to_string(), true), ("b:2".to_string(), false)];
        let s = m.snapshot(&info, 2);
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 1);
        assert_eq!(s.fanouts, 1);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.replica_hits, 2);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.write_fanouts, 1);
        assert_eq!(s.quorum_fails, 1);
        assert_eq!(s.joins, 1);
        assert_eq!(s.drains, 1);
        assert_eq!(s.rebalanced_keys, 17, "join keys + drain keys");
        assert_eq!(s.dropped_keys, 9);
        assert_eq!(s.dual_writes, 1);
        assert_eq!(s.ring_epoch, 2);
        assert_eq!(s.backends[0].requests, 1);
        assert_eq!(s.backends[0].failures, 0);
        assert!(s.backends[0].healthy);
        assert_eq!(s.backends[1].failures, 1);
        assert!(!s.backends[1].healthy);
        assert!(s.backends[1].latency_mean_s > 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let m = RouterMetrics::new(1);
        m.record_query(true);
        m.record_backend(0, true, Duration::from_micros(500));
        let s = m.snapshot(&[("x:1".to_string(), true)], 0);
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        for field in [
            "replica_hits",
            "write_fanouts",
            "quorum_fails",
            "joins",
            "drains",
            "rebalanced_keys",
            "dropped_keys",
            "dual_writes",
            "deadlines_expired",
            "ring_epoch",
        ] {
            assert_eq!(
                back.get(field).and_then(Json::as_f64),
                Some(0.0),
                "{field} missing from the stats payload"
            );
        }
        let backends = back.get("backends").unwrap().as_arr().unwrap();
        assert_eq!(backends[0].get("addr").and_then(Json::as_str), Some("x:1"));
        assert_eq!(backends[0].get("healthy"), Some(&Json::Bool(true)));
    }

    #[test]
    fn throughput_math() {
        let m = RouterMetrics::new(0);
        for _ in 0..50 {
            m.record_query(true);
        }
        let s = m.snapshot(&[], 0);
        assert!((s.throughput(Duration::from_secs(5)) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn membership_changes_grow_and_remap_backend_slots() {
        let m = RouterMetrics::new(2);
        m.record_backend(0, true, Duration::from_millis(1));
        m.record_backend(1, true, Duration::from_millis(1));
        m.record_backend(1, true, Duration::from_millis(1));
        // join: slot 2 appears with empty history
        m.ensure_backends(3);
        m.record_backend(2, true, Duration::from_millis(1));
        let info: Vec<(String, bool)> = ["a:1", "b:2", "c:3"]
            .iter()
            .map(|a| (a.to_string(), true))
            .collect();
        let s = m.snapshot(&info, 1);
        assert_eq!(
            [s.backends[0].requests, s.backends[1].requests, s.backends[2].requests],
            [1, 2, 1]
        );
        // drain of slot 0: later slots shift down with their history
        m.remove_backend(0);
        let info: Vec<(String, bool)> = ["b:2", "c:3"]
            .iter()
            .map(|a| (a.to_string(), true))
            .collect();
        let s = m.snapshot(&info, 2);
        assert_eq!(s.backends.len(), 2);
        assert_eq!(s.backends[0].requests, 2, "b:2 kept its history");
        assert_eq!(s.backends[1].requests, 1);
        // a stale index from the previous membership is dropped, not a
        // panic — and a transiently longer info list only reports the
        // common prefix
        m.record_backend(9, true, Duration::from_millis(1));
        let longer: Vec<(String, bool)> = ["b:2", "c:3", "ghost:9"]
            .iter()
            .map(|a| (a.to_string(), true))
            .collect();
        assert_eq!(m.snapshot(&longer, 2).backends.len(), 2);
    }
}
