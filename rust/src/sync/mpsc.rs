//! Model-checkable mpsc channels (`--features modelcheck`).
//!
//! Construction decides the implementation: a channel created on a
//! model vthread is *virtual* — a `VecDeque` whose send/recv ops are
//! scheduling points, with blocking (bounded send, `recv`) and
//! timeouts (`recv_timeout`, in virtual time) modeled by the
//! scheduler — while a channel created anywhere else wraps the real
//! `std::sync::mpsc` channel and behaves exactly like it. Error types
//! are std's, so call sites compile identically either way.

use std::collections::VecDeque;
use std::fmt;
use std::sync::Arc;
use std::time::Duration;

pub use std::sync::mpsc::{
    RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError,
};

use crate::modelcheck::managed;

const OFF_MODEL: &str =
    "modelcheck channel: a virtual channel endpoint was used outside \
     the model run that created it";

struct VBook<T> {
    queue: VecDeque<T>,
    /// `usize::MAX` encodes an unbounded channel.
    cap: usize,
    senders: usize,
    receiver_alive: bool,
}

struct VChan<T> {
    book: std::sync::Mutex<VBook<T>>,
}

impl<T> VChan<T> {
    fn new(cap: usize) -> Arc<Self> {
        Arc::new(VChan {
            book: std::sync::Mutex::new(VBook {
                queue: VecDeque::new(),
                cap,
                senders: 1,
                receiver_alive: true,
            }),
        })
    }

    fn res(self: &Arc<Self>) -> usize {
        Arc::as_ptr(self) as usize
    }

    fn book(&self) -> std::sync::MutexGuard<'_, VBook<T>> {
        self.book.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Wake parked peers if we are on a model vthread; a plain-thread
    /// drop after the run has no peers left to wake.
    fn wake_peers(self: &Arc<Self>) {
        if let Some((sh, _)) = managed() {
            sh.wake(self.res());
        }
    }

    fn add_sender(&self) {
        self.book().senders += 1;
    }

    fn drop_sender(self: &Arc<Self>) {
        let last = {
            let mut b = self.book();
            b.senders = b.senders.saturating_sub(1);
            b.senders == 0
        };
        if last {
            self.wake_peers();
        }
    }

    fn send_virtual(self: &Arc<Self>, value: T) -> Result<(), SendError<T>> {
        let (sh, vtid) = managed().expect(OFF_MODEL);
        let mut item = Some(value);
        loop {
            sh.yield_point(vtid);
            {
                let mut b = self.book();
                if !b.receiver_alive {
                    return Err(SendError(item.take().expect("send item")));
                }
                if b.queue.len() < b.cap {
                    b.queue.push_back(item.take().expect("send item"));
                    drop(b);
                    self.wake_peers();
                    return Ok(());
                }
            }
            sh.block(vtid, self.res(), "channel-send", None);
        }
    }

    fn try_send_virtual(
        self: &Arc<Self>,
        value: T,
    ) -> Result<(), TrySendError<T>> {
        let (sh, vtid) = managed().expect(OFF_MODEL);
        sh.yield_point(vtid);
        let mut b = self.book();
        if !b.receiver_alive {
            return Err(TrySendError::Disconnected(value));
        }
        if b.queue.len() >= b.cap {
            return Err(TrySendError::Full(value));
        }
        b.queue.push_back(value);
        drop(b);
        self.wake_peers();
        Ok(())
    }

    fn recv_virtual(self: &Arc<Self>) -> Result<T, RecvError> {
        let (sh, vtid) = managed().expect(OFF_MODEL);
        loop {
            sh.yield_point(vtid);
            {
                let mut b = self.book();
                if let Some(v) = b.queue.pop_front() {
                    drop(b);
                    self.wake_peers(); // a bounded sender may fit now
                    return Ok(v);
                }
                if b.senders == 0 {
                    return Err(RecvError);
                }
            }
            sh.block(vtid, self.res(), "channel-recv", None);
        }
    }

    fn try_recv_virtual(self: &Arc<Self>) -> Result<T, TryRecvError> {
        let (sh, vtid) = managed().expect(OFF_MODEL);
        sh.yield_point(vtid);
        let mut b = self.book();
        if let Some(v) = b.queue.pop_front() {
            drop(b);
            self.wake_peers();
            return Ok(v);
        }
        if b.senders == 0 {
            return Err(TryRecvError::Disconnected);
        }
        Err(TryRecvError::Empty)
    }

    fn recv_timeout_virtual(
        self: &Arc<Self>,
        timeout: Duration,
    ) -> Result<T, RecvTimeoutError> {
        let (sh, vtid) = managed().expect(OFF_MODEL);
        let deadline = sh.now_ns() + timeout.as_nanos();
        loop {
            sh.yield_point(vtid);
            {
                let mut b = self.book();
                if let Some(v) = b.queue.pop_front() {
                    drop(b);
                    self.wake_peers();
                    return Ok(v);
                }
                if b.senders == 0 {
                    return Err(RecvTimeoutError::Disconnected);
                }
            }
            let now = sh.now_ns();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let remaining = Duration::from_nanos((deadline - now) as u64);
            sh.block(vtid, self.res(), "channel-recv", Some(remaining));
        }
    }
}

enum SenderImpl<T> {
    Std(std::sync::mpsc::Sender<T>),
    Virt(Arc<VChan<T>>),
}

enum SyncSenderImpl<T> {
    Std(std::sync::mpsc::SyncSender<T>),
    Virt(Arc<VChan<T>>),
}

enum ReceiverImpl<T> {
    Std(std::sync::mpsc::Receiver<T>),
    Virt(Arc<VChan<T>>),
}

/// Drop-in [`std::sync::mpsc::Sender`] (unbounded).
pub struct Sender<T>(SenderImpl<T>);

/// Drop-in [`std::sync::mpsc::SyncSender`] (bounded, blocking send).
pub struct SyncSender<T>(SyncSenderImpl<T>);

/// Drop-in [`std::sync::mpsc::Receiver`].
pub struct Receiver<T>(ReceiverImpl<T>);

/// See [`std::sync::mpsc::channel`]. Virtual when called on a model
/// vthread, real std otherwise.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    if managed().is_some() {
        let chan = VChan::new(usize::MAX);
        (
            Sender(SenderImpl::Virt(Arc::clone(&chan))),
            Receiver(ReceiverImpl::Virt(chan)),
        )
    } else {
        let (tx, rx) = std::sync::mpsc::channel();
        (Sender(SenderImpl::Std(tx)), Receiver(ReceiverImpl::Std(rx)))
    }
}

/// See [`std::sync::mpsc::sync_channel`]. Virtual when called on a
/// model vthread (`bound == 0` rendezvous channels are not modeled),
/// real std otherwise.
pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
    if managed().is_some() {
        assert!(bound > 0, "modelcheck sync_channel: rendezvous (bound 0) is not modeled");
        let chan = VChan::new(bound);
        (
            SyncSender(SyncSenderImpl::Virt(Arc::clone(&chan))),
            Receiver(ReceiverImpl::Virt(chan)),
        )
    } else {
        let (tx, rx) = std::sync::mpsc::sync_channel(bound);
        (
            SyncSender(SyncSenderImpl::Std(tx)),
            Receiver(ReceiverImpl::Std(rx)),
        )
    }
}

impl<T> Sender<T> {
    /// See [`std::sync::mpsc::Sender::send`].
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        match &self.0 {
            SenderImpl::Std(tx) => tx.send(value),
            SenderImpl::Virt(chan) => chan.send_virtual(value),
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        match &self.0 {
            SenderImpl::Std(tx) => Sender(SenderImpl::Std(tx.clone())),
            SenderImpl::Virt(chan) => {
                chan.add_sender();
                Sender(SenderImpl::Virt(Arc::clone(chan)))
            }
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if let SenderImpl::Virt(chan) = &self.0 {
            chan.drop_sender();
        }
    }
}

impl<T> fmt::Debug for Sender<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Sender").finish_non_exhaustive()
    }
}

impl<T> SyncSender<T> {
    /// See [`std::sync::mpsc::SyncSender::send`] — blocks while the
    /// queue is full (a parked vthread under a model run).
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        match &self.0 {
            SyncSenderImpl::Std(tx) => tx.send(value),
            SyncSenderImpl::Virt(chan) => chan.send_virtual(value),
        }
    }

    /// See [`std::sync::mpsc::SyncSender::try_send`].
    pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
        match &self.0 {
            SyncSenderImpl::Std(tx) => tx.try_send(value),
            SyncSenderImpl::Virt(chan) => chan.try_send_virtual(value),
        }
    }
}

impl<T> Clone for SyncSender<T> {
    fn clone(&self) -> Self {
        match &self.0 {
            SyncSenderImpl::Std(tx) => SyncSender(SyncSenderImpl::Std(tx.clone())),
            SyncSenderImpl::Virt(chan) => {
                chan.add_sender();
                SyncSender(SyncSenderImpl::Virt(Arc::clone(chan)))
            }
        }
    }
}

impl<T> Drop for SyncSender<T> {
    fn drop(&mut self) {
        if let SyncSenderImpl::Virt(chan) = &self.0 {
            chan.drop_sender();
        }
    }
}

impl<T> fmt::Debug for SyncSender<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SyncSender").finish_non_exhaustive()
    }
}

impl<T> Receiver<T> {
    /// See [`std::sync::mpsc::Receiver::recv`].
    pub fn recv(&self) -> Result<T, RecvError> {
        match &self.0 {
            ReceiverImpl::Std(rx) => rx.recv(),
            ReceiverImpl::Virt(chan) => chan.recv_virtual(),
        }
    }

    /// See [`std::sync::mpsc::Receiver::try_recv`].
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        match &self.0 {
            ReceiverImpl::Std(rx) => rx.try_recv(),
            ReceiverImpl::Virt(chan) => chan.try_recv_virtual(),
        }
    }

    /// See [`std::sync::mpsc::Receiver::recv_timeout`]. Under a model
    /// run the timeout is virtual: it fires (deterministically) only
    /// when no vthread can make progress before the deadline.
    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> Result<T, RecvTimeoutError> {
        match &self.0 {
            ReceiverImpl::Std(rx) => rx.recv_timeout(timeout),
            ReceiverImpl::Virt(chan) => chan.recv_timeout_virtual(timeout),
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if let ReceiverImpl::Virt(chan) = &self.0 {
            chan.book().receiver_alive = false;
            chan.wake_peers();
        }
    }
}

impl<T> fmt::Debug for Receiver<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Receiver").finish_non_exhaustive()
    }
}
