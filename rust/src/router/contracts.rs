//! Executable elasticity contracts.
//!
//! ROADMAP.md records five elasticity invariants the router and
//! coordinator must keep. This module turns each one into a *named,
//! checkable assertion* wired into the code paths that could break it
//! (`rebalance.rs`, `ring.rs`, `scatter.rs`), so a regression fails a
//! test with the invariant's name instead of surfacing three layers
//! later as a lost key.
//!
//! The checks are **gated**: they run under `debug_assertions` (so
//! every `cargo test` exercises them) and under `--features contracts`
//! (to force them into a release build, e.g. a soak run); a default
//! release build compiles them out entirely. Each checker returns
//! immediately when disabled — no argument is inspected — so the
//! serving hot path pays nothing.
//!
//! The names, in ROADMAP order:
//!
//! | constant | invariant |
//! |---|---|
//! | [`SERVING_SET_FULLY_INDEXED`] | (1) every key's serving set is fully indexed at every instant |
//! | [`EPOCH_GATED_MEMBERSHIP`] | (2) membership changes are numbered by partition epoch and gated by the [`EpochGate`](crate::router::health::EpochGate) |
//! | [`MINIMAL_KEY_MOVEMENT`] | (3) a join/drain moves exactly the keys whose serving set changed |
//! | [`DUAL_WRITE_COVERAGE`] | (4) dynamic writes are idempotent and dual-applied across an in-flight rebalance |
//! | [`SINGLE_FLIGHT_REBALANCE`] | (5) one rebalance at a time, and a failed rebalance changes nothing |
//! | [`CACHE_EPOCH_COHERENT`] | (6) no reply-cache entry outlives its admission epoch |

use crate::filter::fingerprint::entity_key;
use crate::router::health::EpochGate;
use crate::router::rebalance::{serving_addrs, serving_set, RingState};
use crate::router::ring::ShardRing;

/// Invariant (1): every key's serving set is fully indexed at every
/// instant. Checked as: a rebalance plan warms/hands off **every** key
/// whose serving set the new epoch changes (no newly assigned key goes
/// unstreamed), and a replica set never silently under-replicates.
pub const SERVING_SET_FULLY_INDEXED: &str = "serving-set-fully-indexed";

/// Invariant (2): membership changes are numbered by partition epoch
/// (each rebalance is exactly `epoch + 1`) and the epoch gate accepts
/// precisely the epochs the roll is in — both during the dual-write
/// window and after commit.
pub const EPOCH_GATED_MEMBERSHIP: &str = "epoch-gated-membership";

/// Invariant (3): a join/drain moves exactly the keys whose serving
/// set changed — a key that kept its serving set is never streamed.
pub const MINIMAL_KEY_MOVEMENT: &str = "minimal-key-movement";

/// Invariant (4): across an in-flight rebalance, a dynamic write
/// reaches every backend of the **incoming** epoch's serving set too
/// (as current-target ack or pending-extra dual write).
pub const DUAL_WRITE_COVERAGE: &str = "dual-write-coverage";

/// Invariant (5): one rebalance at a time, and a failed rebalance
/// leaves the serving membership exactly as it found it.
pub const SINGLE_FLIGHT_REBALANCE: &str = "single-flight-rebalance";

/// Invariant (6): no reply-cache entry outlives its admission epoch —
/// a cached reply is only ever admitted and served at the membership
/// epoch it was assembled under
/// ([`ReplyCache`](crate::router::cache::ReplyCache) keys entries on
/// the epoch and the rebalance paths flush wholesale, so a violation
/// means the cache and the membership snapshot disagree).
pub const CACHE_EPOCH_COHERENT: &str = "cache-epoch-coherent";

/// All six contract names, in ROADMAP order — what the integration
/// suite enumerates to prove the contracts exist and are spelled
/// consistently.
pub const ALL: [&str; 6] = [
    SERVING_SET_FULLY_INDEXED,
    EPOCH_GATED_MEMBERSHIP,
    MINIMAL_KEY_MOVEMENT,
    DUAL_WRITE_COVERAGE,
    SINGLE_FLIGHT_REBALANCE,
    CACHE_EPOCH_COHERENT,
];

/// Whether contract checks run in this build: every debug/test build,
/// plus release builds compiled with `--features contracts`.
#[inline]
pub fn enabled() -> bool {
    cfg!(any(debug_assertions, feature = "contracts"))
}

/// Assert one contract. `detail` is only evaluated on violation.
#[track_caller]
pub fn check(name: &str, ok: bool, detail: impl FnOnce() -> String) {
    if enabled() && !ok {
        panic!("elasticity contract violated [{name}]: {}", detail());
    }
}

/// Contracts (1) + (3), checked against a rebalance **plan**: `moved`
/// must be exactly the vocabulary keys whose serving *addresses*
/// differ between the outgoing and incoming rings.
///
/// * a changed key missing from `moved` would serve unindexed after
///   the roll — [`SERVING_SET_FULLY_INDEXED`];
/// * an unchanged key present in `moved` is pointless churn the
///   minimal-disruption design promises never happens —
///   [`MINIMAL_KEY_MOVEMENT`].
pub fn check_movement_plan(
    vocab: &[String],
    old_ring: &ShardRing,
    new_ring: &ShardRing,
    replication: usize,
    moved: &[&String],
) {
    if !enabled() {
        return;
    }
    let moved_set: std::collections::HashSet<&str> =
        moved.iter().map(|s| s.as_str()).collect();
    for name in vocab {
        let key = entity_key(name);
        let changed = serving_addrs(old_ring, replication, key)
            != serving_addrs(new_ring, replication, key);
        if changed {
            check(SERVING_SET_FULLY_INDEXED, moved_set.contains(name.as_str()), || {
                format!(
                    "key {name:?} changes its serving set in the next \
                     epoch but is not planned for warm-up/handoff"
                )
            });
        } else {
            check(MINIMAL_KEY_MOVEMENT, !moved_set.contains(name.as_str()), || {
                format!(
                    "key {name:?} keeps its serving set yet is planned \
                     to move"
                )
            });
        }
    }
}

/// Contract (2) at window-open plus contract (5)'s single-flight half:
/// the outgoing generation has no rebalance in flight, the incoming
/// epoch is exactly `current + 1`, and after [`EpochGate::open`] the
/// gate accepts both epochs of the roll.
pub fn check_window_open(
    current: &RingState,
    pending_epoch: u64,
    gate: &EpochGate,
) {
    if !enabled() {
        return;
    }
    check(SINGLE_FLIGHT_REBALANCE, current.pending.is_none(), || {
        format!(
            "opening a dual-write window at epoch {pending_epoch} while \
             another rebalance is pending"
        )
    });
    check(EPOCH_GATED_MEMBERSHIP, pending_epoch == current.epoch + 1, || {
        format!(
            "membership change must be numbered {} (current epoch + 1), \
             got {pending_epoch}",
            current.epoch + 1
        )
    });
    check(
        EPOCH_GATED_MEMBERSHIP,
        gate.accepts(current.epoch) && gate.accepts(pending_epoch),
        || {
            format!(
                "during the roll the gate must accept both epoch {} and \
                 epoch {pending_epoch}",
                current.epoch
            )
        },
    );
}

/// Contract (2) at commit: the gate was opened for this epoch, and
/// after [`EpochGate::commit`] it serves exactly this epoch (stale
/// members now fail probes). Call with `committed = false` before the
/// swap and `committed = true` after.
pub fn check_commit(gate: &EpochGate, epoch: u64, committed: bool) {
    if !enabled() {
        return;
    }
    if committed {
        check(EPOCH_GATED_MEMBERSHIP, gate.current() == epoch, || {
            format!(
                "after commit the gate must serve epoch {epoch}, it \
                 serves {}",
                gate.current()
            )
        });
    } else {
        check(EPOCH_GATED_MEMBERSHIP, gate.accepts(epoch), || {
            format!(
                "committing epoch {epoch} which the gate never accepted \
                 (window was not opened)"
            )
        });
    }
}

/// Contract (5), abort half: a failed rebalance changes nothing — the
/// serving epoch, the member addresses, and the (now absent) pending
/// state all match the pre-rebalance snapshot.
pub fn check_abort_unchanged(before: &RingState, after: &RingState) {
    if !enabled() {
        return;
    }
    check(SINGLE_FLIGHT_REBALANCE, after.pending.is_none(), || {
        "aborted rebalance left a pending generation installed".into()
    });
    check(
        SINGLE_FLIGHT_REBALANCE,
        after.epoch == before.epoch && after.addresses() == before.addresses(),
        || {
            format!(
                "aborted rebalance changed the serving membership: epoch \
                 {} -> {}, members {:?} -> {:?}",
                before.epoch,
                after.epoch,
                before.addresses(),
                after.addresses()
            )
        },
    );
}

/// Contract (4): while a rebalance is in flight, the write fan-out for
/// `key` covers every backend of the **pending** epoch's serving set —
/// either as a current-epoch target or as a dual-write extra.
/// `covered` answers "does this fan-out reach address `a`?".
pub fn check_dual_write_coverage(
    pending_ring: &ShardRing,
    replication: usize,
    key: u64,
    covered: impl Fn(&str) -> bool,
) {
    if !enabled() {
        return;
    }
    for i in serving_set(pending_ring, replication, key) {
        let addr = pending_ring.name(i);
        check(DUAL_WRITE_COVERAGE, covered(addr), || {
            format!(
                "mid-rebalance write misses {addr}, a member of the \
                 incoming epoch's serving set for this key"
            )
        });
    }
}

/// Contract (6): a reply-cache entry served or admitted at
/// `serving_epoch` must carry exactly that epoch as its admission
/// epoch — no entry outlives the membership generation it was
/// assembled under. Checked at every cache hit and fill site.
pub fn check_cache_epoch(entry_epoch: u64, serving_epoch: u64) {
    if !enabled() {
        return;
    }
    check(CACHE_EPOCH_COHERENT, entry_epoch == serving_epoch, || {
        format!(
            "cache entry admitted at epoch {entry_epoch} touched while \
             serving epoch {serving_epoch}"
        )
    });
}

/// Contract (1), replica-set half: a serving replica set must hold
/// `min(max(r,1), ring len)` **distinct** members — duplicates or a
/// short set would silently under-replicate every key it serves.
pub fn check_replica_set(ring_len: usize, r: usize, set: &[usize]) {
    if !enabled() {
        return;
    }
    check(
        SERVING_SET_FULLY_INDEXED,
        set.len() == r.max(1).min(ring_len),
        || {
            format!(
                "replica set size {} for r={r} on a {ring_len}-member ring",
                set.len()
            )
        },
    );
    let distinct: std::collections::HashSet<usize> =
        set.iter().copied().collect();
    check(SERVING_SET_FULLY_INDEXED, distinct.len() == set.len(), || {
        format!("replica set {set:?} contains duplicate members")
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize) -> ShardRing {
        ShardRing::new((0..n).map(|i| format!("b{i}")))
    }

    #[test]
    fn contracts_run_in_test_builds() {
        assert!(enabled(), "debug/test builds must enforce the contracts");
        assert_eq!(ALL.len(), 6);
    }

    #[test]
    fn cache_epoch_check_rejects_cross_epoch_entries() {
        check_cache_epoch(3, 3);
        let err =
            std::panic::catch_unwind(|| check_cache_epoch(2, 3)).expect_err(
                "an entry outliving its admission epoch must violate (6)",
            );
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(CACHE_EPOCH_COHERENT), "{msg}");
    }

    #[test]
    fn movement_plan_flags_missing_and_spurious_keys() {
        let old = ring(2);
        let new = ShardRing::new(["b0", "b1", "b2"].map(String::from));
        let vocab: Vec<String> =
            (0..64).map(|i| format!("entity-{i}")).collect();
        // the correct plan: exactly the keys whose serving set changed
        let correct: Vec<&String> = vocab
            .iter()
            .filter(|n| {
                serving_addrs(&old, 1, entity_key(n))
                    != serving_addrs(&new, 1, entity_key(n))
            })
            .collect();
        assert!(!correct.is_empty(), "a 3rd member must win some keys");
        check_movement_plan(&vocab, &old, &new, 1, &correct);

        // dropping one changed key violates (1)
        let short = &correct[1..];
        let err = std::panic::catch_unwind(|| {
            check_movement_plan(&vocab, &old, &new, 1, short)
        })
        .expect_err("under-planned move must violate the contract");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(SERVING_SET_FULLY_INDEXED), "{msg}");

        // adding an unchanged key violates (3)
        let unchanged = vocab
            .iter()
            .find(|n| !correct.iter().any(|c| c == n))
            .expect("some key keeps its serving set");
        let mut over = correct.clone();
        over.push(unchanged);
        let err = std::panic::catch_unwind(|| {
            check_movement_plan(&vocab, &old, &new, 1, &over)
        })
        .expect_err("over-planned move must violate the contract");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(MINIMAL_KEY_MOVEMENT), "{msg}");
    }

    #[test]
    fn replica_set_check_rejects_duplicates_and_short_sets() {
        check_replica_set(3, 2, &[0, 2]);
        assert!(std::panic::catch_unwind(|| {
            check_replica_set(3, 2, &[1, 1])
        })
        .is_err());
        assert!(std::panic::catch_unwind(|| {
            check_replica_set(3, 2, &[0])
        })
        .is_err());
    }

    #[test]
    fn dual_write_coverage_names_the_missed_member() {
        let pending = ring(3);
        // full coverage passes
        check_dual_write_coverage(&pending, 2, 42, |_| true);
        let err = std::panic::catch_unwind(|| {
            check_dual_write_coverage(&pending, 2, 42, |_| false)
        })
        .expect_err("uncovered pending member must violate the contract");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains(DUAL_WRITE_COVERAGE), "{msg}");
    }
}
