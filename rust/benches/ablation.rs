//! Design-choice ablations beyond Figure 5: bucket slots {2,4,8} x
//! fingerprint bits {8,12,16} x sorting {on,off} — retrieval time and
//! index memory (DESIGN.md per-experiment index).
//!
//! Run: `cargo bench --bench ablation`. Writes `results/ablation.csv`.

use cft_rag::bench::experiments::{ablation, ExperimentConfig};
use cft_rag::util::cli::{spec, Args};

fn main() {
    let args = Args::from_env(vec![
        spec("trees", "tree count", Some("300"), false),
        spec("queries", "queries per workload", Some("100"), false),
        spec("repeats", "timed repeats", Some("10"), false),
        spec("out", "CSV output path", Some("results/ablation.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let cfg = ExperimentConfig {
        queries: args.num_or("queries", 100),
        repeats: args.num_or("repeats", 10),
        ..ExperimentConfig::default()
    };
    let csv = ablation(cfg, args.num_or("trees", 300));
    let out = args.str_or("out", "results/ablation.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");
}
