"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once by ``make artifacts``; Python never runs at serve time. The Rust
runtime loads these with ``HloModuleProto::from_text_file`` and compiles
them on the PJRT CPU client.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly. Lowering goes
through ``return_tuple=True`` so every artifact's output is a 1-tuple the
Rust side unwraps with ``to_tuple1()``.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text via stablehlo.

    ``as_hlo_text(True)`` = print_large_constants: the embedder's
    FREQ/PHASE/GAMMA weight vectors must be materialized in the text, or
    the parser on the Rust side reads them back as zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "large constants were elided from HLO text"
    return text


# artifact name -> (fn, example-arg specs)
ARTIFACTS = {
    "embed": (model.embed, model.embed_specs),
    "score": (model.score, model.score_specs),
    "rank": (model.rank, model.rank_specs),
}


def build_manifest() -> dict:
    """Shape/dtype manifest consumed by rust/src/runtime/artifact.rs."""
    return {
        "version": 1,
        "embed_dim": model.EMBED_DIM,
        "max_tokens": model.MAX_TOKENS,
        "shard_docs": model.SHARD_DOCS,
        "max_facts": model.MAX_FACTS,
        "batch": model.BATCH,
        "pad_id": model.PAD_ID,
        "artifacts": {
            name: {
                "file": f"{name}.hlo.txt",
                "inputs": [
                    {"shape": list(s.shape), "dtype": s.dtype.name}
                    for s in specs()
                ],
            }
            for name, (_, specs) in ARTIFACTS.items()
        },
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="comma-separated artifact names"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    for name, (fn, specs) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs())
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(build_manifest(), f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
