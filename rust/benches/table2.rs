//! Reproduces **Table 2**: retrieval time vs entities-per-query
//! {5, 10, 20} at 600 trees.
//!
//! Run: `cargo bench --bench table2`. Writes `results/table2.csv`.

use cft_rag::bench::experiments::{table2, ExperimentConfig};
use cft_rag::util::cli::{spec, Args};

fn main() {
    let args = Args::from_env(vec![
        spec("trees", "tree count", Some("600"), false),
        spec("entities", "comma-separated entities/query", Some("5,10,20"), false),
        spec("queries", "queries per workload", Some("100"), false),
        spec("repeats", "timed repeats", Some("10"), false),
        spec("out", "CSV output path", Some("results/table2.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let cfg = ExperimentConfig {
        queries: args.num_or("queries", 100),
        repeats: args.num_or("repeats", 10),
        ..ExperimentConfig::default()
    };
    let entities: Vec<usize> = args.list_or("entities", &[5, 10, 20]);
    let csv = table2(cfg, args.num_or("trees", 600), &entities);
    let out = args.str_or("out", "results/table2.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");
}
