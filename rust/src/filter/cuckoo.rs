//! The improved Cuckoo Filter — the paper's core contribution (§3).
//!
//! A partial-key cuckoo hash table (Fan et al. 2014) whose entries carry,
//! besides the fingerprint, the paper's two additions:
//!
//! * a **temperature** — access counter bumped on every hit; buckets are
//!   re-sorted by descending temperature during maintenance so linear
//!   in-bucket scans hit hot entities first (§3.1, ablated in Figure 5);
//! * the **head of a block linked list** of all forest addresses of the
//!   entity (§3.1), so one O(1) lookup replaces a whole forest BFS.
//!
//! Layout is struct-of-arrays: the hot fingerprint array is scanned on
//! lookup; temperatures, list heads and the (cold) original keys live in
//! parallel arrays touched only on hits, maintenance, and expansion.
//! Expansion doubles the bucket count and re-inserts every live entry
//! from its stored key — mirroring the paper's "original elements are
//! re-hashed and migrated" description (the C++ original equally retains
//! entities to re-hash; the key array is the cold-path cost of dynamic
//! growth).
//!
//! **Concurrency:** temperatures and per-bucket dirty flags are atomics,
//! so [`CuckooFilter::lookup_shared`] works through `&self` — many
//! readers can probe in parallel under a shard *read* lock (see
//! `filter::sharded`), with temperature bumps as relaxed increments.
//! Every structural mutation (insert / delete / maintain / expansion)
//! still takes `&mut self` and therefore an exclusive lock.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering::Relaxed};

use crate::filter::blocklist::{BlockArena, NIL};
use crate::filter::fingerprint::{alt_index, fingerprint, primary_index};
use crate::forest::EntityAddress;
use crate::util::rng::Rng;

/// Tunables (paper values as defaults).
#[derive(Clone, Copy, Debug)]
pub struct CuckooConfig {
    /// Initial bucket count (rounded up to a power of two). Paper: 1024.
    pub initial_buckets: usize,
    /// Slots per bucket. Paper: 4.
    pub slots: usize,
    /// Fingerprint width in bits. Paper: 12.
    pub fingerprint_bits: u32,
    /// Max displacement chain length before declaring the table full.
    pub max_kicks: usize,
    /// Expand when load factor would exceed this.
    pub load_threshold: f64,
    /// Adaptive temperature sorting (§3.1) — ablation switch.
    pub sort_by_temperature: bool,
    /// RNG seed for eviction victim choice.
    pub seed: u64,
}

impl Default for CuckooConfig {
    fn default() -> Self {
        CuckooConfig {
            initial_buckets: 1024,
            slots: 4,
            fingerprint_bits: 12,
            max_kicks: 500,
            load_threshold: 0.94,
            sort_by_temperature: true,
            seed: 0xCF17_4A06,
        }
    }
}

/// Counters reported by benches and EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, Default)]
pub struct CuckooStats {
    pub inserts: u64,
    pub kicks: u64,
    pub expansions: u64,
    pub lookups: u64,
    /// slots probed across all lookups (the metric temperature sorting improves)
    pub slots_probed: u64,
}

impl CuckooStats {
    /// Sum counters (sharded-filter aggregation).
    pub fn merge(&mut self, other: CuckooStats) {
        self.inserts += other.inserts;
        self.kicks += other.kicks;
        self.expansions += other.expansions;
        self.lookups += other.lookups;
        self.slots_probed += other.slots_probed;
    }
}

/// A successful lookup: the entity's block-list head.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LookupHit {
    /// Head of the block linked list of addresses (NIL if entity was
    /// inserted with no addresses).
    pub head: u32,
}

/// An entry carried between table generations: (key, temperature, head).
type Entry = (u64, u32, u32);

/// The two candidate buckets of a key, deduplicated: when `i1 == i2`
/// (which partial-key hashing does produce), the bucket is yielded once
/// so no probe site scans — or counts — the same slots twice.
#[inline]
fn bucket_pair(i1: usize, i2: usize) -> impl Iterator<Item = usize> {
    std::iter::once(i1).chain((i2 != i1).then_some(i2))
}

/// The improved Cuckoo Filter.
#[derive(Debug)]
pub struct CuckooFilter {
    cfg: CuckooConfig,
    nbuckets: usize,
    /// hot path: fingerprints, 0 = empty slot; len = nbuckets * slots
    fps: Vec<u16>,
    /// temperature per slot (atomic: bumped by shared-borrow lookups)
    temps: Vec<AtomicU32>,
    /// block-list head per slot (NIL when none)
    heads: Vec<u32>,
    /// cold path: original keys, used for expansion & exact-match checks
    keys: Vec<u64>,
    /// buckets whose temperature order may be stale
    dirty: Vec<AtomicBool>,
    arena: BlockArena,
    len: usize,
    rng: Rng,
    /// write-path counters (inserts / kicks / expansions)
    stats: CuckooStats,
    /// read-path counters, atomic so `lookup_shared` can record them
    lookups: AtomicU64,
    slots_probed: AtomicU64,
}

impl Default for CuckooFilter {
    fn default() -> Self {
        Self::new(CuckooConfig::default())
    }
}

impl Clone for CuckooFilter {
    fn clone(&self) -> Self {
        CuckooFilter {
            cfg: self.cfg,
            nbuckets: self.nbuckets,
            fps: self.fps.clone(),
            temps: self
                .temps
                .iter()
                .map(|t| AtomicU32::new(t.load(Relaxed)))
                .collect(),
            heads: self.heads.clone(),
            keys: self.keys.clone(),
            dirty: self
                .dirty
                .iter()
                .map(|d| AtomicBool::new(d.load(Relaxed)))
                .collect(),
            arena: self.arena.clone(),
            len: self.len,
            rng: self.rng.clone(),
            stats: self.stats,
            lookups: AtomicU64::new(self.lookups.load(Relaxed)),
            slots_probed: AtomicU64::new(self.slots_probed.load(Relaxed)),
        }
    }
}

impl CuckooFilter {
    /// New filter with the given configuration.
    pub fn new(cfg: CuckooConfig) -> Self {
        let nbuckets = cfg.initial_buckets.next_power_of_two().max(1);
        let slots = nbuckets * cfg.slots;
        CuckooFilter {
            nbuckets,
            fps: vec![0; slots],
            temps: std::iter::repeat_with(|| AtomicU32::new(0))
                .take(slots)
                .collect(),
            heads: vec![NIL; slots],
            keys: vec![0; slots],
            dirty: std::iter::repeat_with(|| AtomicBool::new(false))
                .take(nbuckets)
                .collect(),
            arena: BlockArena::new(),
            len: 0,
            rng: Rng::new(cfg.seed),
            stats: CuckooStats::default(),
            lookups: AtomicU64::new(0),
            slots_probed: AtomicU64::new(0),
            cfg,
        }
    }

    /// Entries stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Current bucket count.
    pub fn buckets(&self) -> usize {
        self.nbuckets
    }

    /// Slots per bucket (configuration).
    pub fn slots_per_bucket(&self) -> usize {
        self.cfg.slots
    }

    /// Load factor: occupied slots / total slots.
    pub fn load_factor(&self) -> f64 {
        self.len as f64 / (self.nbuckets * self.cfg.slots) as f64
    }

    /// Counters (snapshot; read-path counters are atomics).
    pub fn stats(&self) -> CuckooStats {
        let mut s = self.stats;
        s.lookups = self.lookups.load(Relaxed);
        s.slots_probed = self.slots_probed.load(Relaxed);
        s
    }

    /// The block arena (for reading address lists from a [`LookupHit`]).
    pub fn arena(&self) -> &BlockArena {
        &self.arena
    }

    /// Approximate heap usage in bytes (hot + cold + arena).
    pub fn memory_bytes(&self) -> usize {
        self.fps.capacity() * 2
            + self.temps.capacity() * 4
            + self.heads.capacity() * 4
            + self.keys.capacity() * 8
            + self.dirty.capacity()
            + self.arena.memory_bytes()
    }

    /// Bytes on the lookup-critical path only (fingerprint array).
    pub fn hot_bytes(&self) -> usize {
        self.fps.capacity() * 2
    }

    #[inline]
    fn slot_range(&self, bucket: usize) -> std::ops::Range<usize> {
        bucket * self.cfg.slots..(bucket + 1) * self.cfg.slots
    }

    // ---------------------------------------------------------------
    // Insertion (paper Algorithm 1)
    // ---------------------------------------------------------------

    /// Insert an entity (by key) with all its forest addresses.
    ///
    /// Duplicate keys are rejected (`false`); use [`push_address`] to grow
    /// an existing entry. Expands automatically, so insertion of a fresh
    /// key always succeeds.
    ///
    /// [`push_address`]: CuckooFilter::push_address
    pub fn insert(&mut self, key: u64, addrs: &[EntityAddress]) -> bool {
        // Exact duplicate check on the cold keys — a fingerprint-only
        // check would misreject fresh keys on fingerprint collisions.
        if self.contains_exact(key) {
            return false;
        }
        if self.load_factor_after_insert() > self.cfg.load_threshold {
            self.expand();
        }
        let head = self.arena.build(addrs);
        self.place(key, 0, head);
        self.len += 1;
        self.stats.inserts += 1;
        true
    }

    fn load_factor_after_insert(&self) -> f64 {
        (self.len + 1) as f64 / (self.nbuckets * self.cfg.slots) as f64
    }

    /// Place an entry, expanding until it fits. A failed kick chain
    /// leaves the new entry placed and one displaced *victim* homeless
    /// (`try_place_no_expand` hands it back); the victim — never the
    /// table — is what gets re-placed after the doubling, so no entry is
    /// ever dropped and no key is ever placed twice.
    fn place(&mut self, key: u64, temp: u32, head: u32) {
        let mut cur = (key, temp, head);
        loop {
            match self.try_place_no_expand(cur.0, cur.1, cur.2) {
                Ok(()) => return,
                Err(homeless) => {
                    cur = homeless;
                    self.expand();
                }
            }
        }
    }

    fn empty_slot(&self, bucket: usize) -> Option<usize> {
        self.slot_range(bucket).find(|&s| self.fps[s] == 0)
    }

    fn write_slot(&mut self, s: usize, fp: u16, key: u64, temp: u32, head: u32) {
        self.fps[s] = fp;
        self.keys[s] = key;
        *self.temps[s].get_mut() = temp;
        self.heads[s] = head;
        *self.dirty[s / self.cfg.slots].get_mut() = true;
    }

    // ---------------------------------------------------------------
    // Lookup + context entry point (paper §3.4)
    // ---------------------------------------------------------------

    /// Membership probe by fingerprint only — the classic cuckoo-filter
    /// query, subject to fingerprint false positives.
    pub fn contains(&self, key: u64) -> bool {
        let (fp, i1, i2) = self.probe(key);
        bucket_pair(i1, i2).any(|b| self.find_fp(b, fp).is_some())
    }

    /// Exact membership: fingerprint match confirmed against the stored
    /// key (cold path; used by insert's duplicate check and tests).
    pub fn contains_exact(&self, key: u64) -> bool {
        self.find_exact(key).is_some()
    }

    /// Slot index of the exact key, if present.
    #[inline]
    fn find_exact(&self, key: u64) -> Option<usize> {
        let (fp, i1, i2) = self.probe(key);
        for b in bucket_pair(i1, i2) {
            for s in self.slot_range(b) {
                if self.fps[s] == fp && self.keys[s] == key {
                    return Some(s);
                }
            }
        }
        None
    }

    /// Lookup: on a fingerprint hit, bump the entity's temperature and
    /// return its block-list head (paper §3.4). Probes at most two
    /// buckets; within a bucket the scan is linear, which is what the
    /// temperature ordering accelerates.
    pub fn lookup(&mut self, key: u64) -> Option<LookupHit> {
        self.lookup_shared(key)
    }

    /// [`lookup`](CuckooFilter::lookup) through a shared borrow — the
    /// concurrent read path. The structure is not mutated: the
    /// temperature bump is a relaxed atomic increment and the bucket's
    /// dirty flag a relaxed store, so any number of threads may call this
    /// concurrently (each under a shard read lock when sharded).
    pub fn lookup_shared(&self, key: u64) -> Option<LookupHit> {
        self.lookups.fetch_add(1, Relaxed);
        let (fp, i1, i2) = self.probe(key);
        for b in bucket_pair(i1, i2) {
            if let Some(s) = self.find_fp_counting(b, fp) {
                // saturating atomic bump: never wraps hot counters to 0
                let _ = self.temps[s]
                    .fetch_update(Relaxed, Relaxed, |t| t.checked_add(1));
                self.dirty[b].store(true, Relaxed);
                return Some(LookupHit { head: self.heads[s] });
            }
        }
        None
    }

    /// All addresses for a hit (collects the block list).
    pub fn addresses(&self, hit: LookupHit) -> Vec<EntityAddress> {
        self.arena.iter(hit.head).collect()
    }

    /// Iterate a hit's addresses without allocating.
    pub fn addresses_iter(
        &self,
        hit: LookupHit,
    ) -> impl Iterator<Item = EntityAddress> + '_ {
        self.arena.iter(hit.head)
    }

    #[inline]
    fn probe(&self, key: u64) -> (u16, usize, usize) {
        let fp = fingerprint(key, self.cfg.fingerprint_bits);
        let i1 = primary_index(key, self.nbuckets);
        let i2 = alt_index(i1, fp, self.nbuckets);
        (fp, i1, i2)
    }

    /// One 64-bit load of a 4-slot bucket's fingerprints (the default
    /// layout: 4 × u16 = one word). Requires `cfg.slots == 4`.
    #[inline]
    fn bucket_word(&self, bucket: usize) -> u64 {
        debug_assert_eq!(self.cfg.slots, 4);
        let base = bucket * 4;
        debug_assert!(base + 4 <= self.fps.len());
        // SAFETY: fps holds nbuckets*4 contiguous u16s; base+4 <= len.
        unsafe { (self.fps.as_ptr().add(base) as *const u64).read_unaligned() }
    }

    /// SWAR scan of one 4-lane fingerprint word: returns the first slot
    /// holding `fp` (if any before the first empty lane) and the number
    /// of slots a linear scan would have probed — so temperature-sorting
    /// statistics stay exact while the scan itself is branch-light.
    ///
    /// Buckets are left-packed (inserts fill the first hole, deletes
    /// compact), so lanes at/after the first empty lane are all zero.
    #[inline]
    fn scan4(word: u64, fp: u16) -> (Option<usize>, u64) {
        const LO: u64 = 0x0001_0001_0001_0001;
        const HI: u64 = 0x8000_8000_8000_8000;
        let pat = (fp as u64).wrapping_mul(LO); // broadcast fp to 4 lanes
        let x = word ^ pat; // zero lane <=> fingerprint match
        // first-zero-lane detection; the lowest flagged lane is exact
        let hit = x.wrapping_sub(LO) & !x & HI;
        let empty = word.wrapping_sub(LO) & !word & HI;
        let hit_pos = (hit.trailing_zeros() / 16) as usize; // 4 if none
        let empty_pos = (empty.trailing_zeros() / 16) as usize; // 4 if none
        if hit != 0 && hit_pos < empty_pos {
            (Some(hit_pos), hit_pos as u64 + 1)
        } else {
            // linear scan would probe up to and including the first
            // empty slot, or the whole bucket
            (None, (empty_pos + 1).min(4) as u64)
        }
    }

    #[inline]
    fn find_fp(&self, bucket: usize, fp: u16) -> Option<usize> {
        if self.cfg.slots == 4 {
            let (pos, _) = Self::scan4(self.bucket_word(bucket), fp);
            return pos.map(|p| bucket * 4 + p);
        }
        for s in self.slot_range(bucket) {
            if self.fps[s] == fp {
                return Some(s);
            }
            if self.fps[s] == 0 {
                return None; // left-packed: rest of the bucket is empty
            }
        }
        None
    }

    /// Like `find_fp` but records how many slots were probed (the
    /// quantity temperature sorting minimizes). Buckets are kept
    /// left-packed (inserts fill the first empty slot, deletes compact),
    /// so the scan terminates at the first empty slot.
    #[inline]
    fn find_fp_counting(&self, bucket: usize, fp: u16) -> Option<usize> {
        if self.cfg.slots == 4 {
            let (pos, probes) = Self::scan4(self.bucket_word(bucket), fp);
            self.slots_probed.fetch_add(probes, Relaxed);
            return pos.map(|p| bucket * 4 + p);
        }
        let base = bucket * self.cfg.slots;
        for off in 0..self.cfg.slots {
            self.slots_probed.fetch_add(1, Relaxed);
            let cur = self.fps[base + off];
            if cur == fp {
                return Some(base + off);
            }
            if cur == 0 {
                return None; // left-packed: rest of the bucket is empty
            }
        }
        None
    }

    // ---------------------------------------------------------------
    // Deletion (paper Algorithm 2)
    // ---------------------------------------------------------------

    /// Remove an entity by key. Exact (keys compared on the cold path to
    /// avoid deleting a fingerprint-colliding neighbour). The entity's
    /// block list is returned to the arena free list, so insert/delete
    /// churn does not grow the arena. Returns whether an entry was
    /// removed.
    pub fn delete(&mut self, key: u64) -> bool {
        let Some(s) = self.find_exact(key) else {
            return false;
        };
        let b = s / self.cfg.slots;
        self.arena.free_chain(self.heads[s]);
        self.fps[s] = 0;
        self.keys[s] = 0;
        *self.temps[s].get_mut() = 0;
        self.heads[s] = NIL;
        self.compact_bucket(b, s);
        *self.dirty[b].get_mut() = true;
        self.len -= 1;
        true
    }

    /// Restore the left-packed invariant after clearing slot `hole`:
    /// shift the occupied suffix of the bucket one slot left (order of
    /// survivors — and thus temperature order — is preserved).
    fn compact_bucket(&mut self, bucket: usize, hole: usize) {
        let end = (bucket + 1) * self.cfg.slots;
        let mut dst = hole;
        for src in hole + 1..end {
            if self.fps[src] == 0 {
                break;
            }
            self.swap_slots(dst, src);
            dst += 1;
        }
    }

    /// Append a new forest address to an existing entity (dynamic update
    /// path: a new tree mentions a known entity). Exact-match on key.
    pub fn push_address(&mut self, key: u64, addr: EntityAddress) -> bool {
        let Some(s) = self.find_exact(key) else {
            return false;
        };
        self.heads[s] = self.arena.push(self.heads[s], addr);
        true
    }

    // ---------------------------------------------------------------
    // Maintenance: adaptive temperature sorting (§3.1) + expansion
    // ---------------------------------------------------------------

    /// Re-sort dirty buckets by descending temperature ("for each bucket,
    /// if it is free, sort" — we run it between query rounds, exactly how
    /// the paper's experiment uses idle time). No-op when the ablation
    /// switch `sort_by_temperature` is off.
    pub fn maintain(&mut self) {
        if !self.cfg.sort_by_temperature {
            return;
        }
        for b in 0..self.nbuckets {
            if *self.dirty[b].get_mut() {
                self.sort_bucket(b);
                *self.dirty[b].get_mut() = false;
            }
        }
    }

    /// Insertion-sort one bucket's slots: occupied before empty, higher
    /// temperature first. Buckets have ≤ 8 slots, so insertion sort wins.
    fn sort_bucket(&mut self, bucket: usize) {
        let base = bucket * self.cfg.slots;
        let n = self.cfg.slots;
        for i in 1..n {
            let mut j = i;
            while j > 0 && self.slot_less(base + j - 1, base + j) {
                self.swap_slots(base + j - 1, base + j);
                j -= 1;
            }
        }
    }

    /// Ordering: occupied (fp != 0) outranks empty; then temperature desc.
    #[inline]
    fn slot_less(&self, a: usize, b: usize) -> bool {
        let occ_a = self.fps[a] != 0;
        let occ_b = self.fps[b] != 0;
        match (occ_a, occ_b) {
            (false, true) => true,
            (true, true) => {
                self.temps[a].load(Relaxed) < self.temps[b].load(Relaxed)
            }
            _ => false,
        }
    }

    #[inline]
    fn swap_slots(&mut self, a: usize, b: usize) {
        self.fps.swap(a, b);
        self.keys.swap(a, b);
        self.temps.swap(a, b);
        self.heads.swap(a, b);
    }

    /// Every live entry currently in the table.
    fn collect_live(&self) -> Vec<Entry> {
        let mut live = Vec::with_capacity(self.len);
        for s in 0..self.fps.len() {
            if self.fps[s] != 0 {
                live.push((
                    self.keys[s],
                    self.temps[s].load(Relaxed),
                    self.heads[s],
                ));
            }
        }
        live
    }

    /// Replace the table arrays with empty ones of `nbuckets` buckets.
    fn reset_table(&mut self, nbuckets: usize) {
        let slots = nbuckets * self.cfg.slots;
        self.fps = vec![0; slots];
        self.keys = vec![0; slots];
        self.temps = std::iter::repeat_with(|| AtomicU32::new(0))
            .take(slots)
            .collect();
        self.heads = vec![NIL; slots];
        self.dirty = std::iter::repeat_with(|| AtomicBool::new(false))
            .take(nbuckets)
            .collect();
        self.nbuckets = nbuckets;
    }

    /// Double the bucket count and migrate every live entry by re-hashing
    /// its stored key (paper §1: "double expansion ... re-hashed and
    /// migrated"). Temperatures and block lists move with their entries;
    /// the arena is shared and untouched.
    ///
    /// The live set is snapshotted **once**, up front, and each doubling
    /// attempt replays it into a fresh table. A migration collision storm
    /// (vanishingly rare) therefore discards only the partial target
    /// table and retries at double the size — it can never drop the
    /// unmigrated suffix or an in-flight kick victim, which the previous
    /// in-place retry loop did.
    fn expand(&mut self) {
        let live = self.collect_live();
        let mut new_n = self.nbuckets * 2;
        loop {
            self.reset_table(new_n);
            self.stats.expansions += 1;
            let ok = live
                .iter()
                .all(|&(k, t, h)| self.try_place_no_expand(k, t, h).is_ok());
            if ok {
                return;
            }
            new_n *= 2;
        }
    }

    /// Place without expanding. On a failed kick chain the input entry is
    /// already in the table (the first write of the chain) and the final
    /// displaced victim is handed back as `Err` for the caller to re-home
    /// — nothing is silently dropped.
    fn try_place_no_expand(
        &mut self,
        key: u64,
        temp: u32,
        head: u32,
    ) -> Result<(), Entry> {
        let fp = fingerprint(key, self.cfg.fingerprint_bits);
        let i1 = primary_index(key, self.nbuckets);
        let i2 = alt_index(i1, fp, self.nbuckets);
        for b in bucket_pair(i1, i2) {
            if let Some(s) = self.empty_slot(b) {
                self.write_slot(s, fp, key, temp, head);
                return Ok(());
            }
        }
        let mut i = if self.rng.chance(0.5) { i1 } else { i2 };
        let mut cur = (fp, key, temp, head);
        for _ in 0..self.cfg.max_kicks {
            // evict a random resident entry
            let s = i * self.cfg.slots + self.rng.range(0, self.cfg.slots);
            let victim = (
                self.fps[s],
                self.keys[s],
                self.temps[s].load(Relaxed),
                self.heads[s],
            );
            self.write_slot(s, cur.0, cur.1, cur.2, cur.3);
            cur = victim;
            self.stats.kicks += 1;

            i = alt_index(i, cur.0, self.nbuckets);
            if let Some(s2) = self.empty_slot(i) {
                self.write_slot(s2, cur.0, cur.1, cur.2, cur.3);
                return Ok(());
            }
        }
        Err((cur.1, cur.2, cur.3))
    }

    /// Temperature of a key (exact match), if present. Test/bench helper.
    pub fn temperature(&self, key: u64) -> Option<u32> {
        self.find_exact(key).map(|s| self.temps[s].load(Relaxed))
    }

    /// Position (0-based) of the key's slot within its bucket — lower is
    /// cheaper to find. Exposes the effect of temperature sorting.
    pub fn bucket_position(&self, key: u64) -> Option<usize> {
        self.find_exact(key).map(|s| s % self.cfg.slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::fingerprint::entity_key;

    fn addrs(n: u32) -> Vec<EntityAddress> {
        (0..n).map(|i| EntityAddress::new(i, i * 2)).collect()
    }

    fn key(i: u64) -> u64 {
        entity_key(&format!("entity-{i}"))
    }

    #[test]
    fn insert_then_lookup_returns_addresses() {
        let mut cf = CuckooFilter::default();
        let a = addrs(5);
        assert!(cf.insert(key(1), &a));
        let hit = cf.lookup(key(1)).expect("hit");
        assert_eq!(cf.addresses(hit), a);
    }

    #[test]
    fn missing_key_misses() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(1));
        assert!(cf.lookup(key(2)).is_none());
        assert!(!cf.contains(key(2)));
    }

    #[test]
    fn duplicate_insert_rejected() {
        let mut cf = CuckooFilter::default();
        assert!(cf.insert(key(1), &addrs(1)));
        assert!(!cf.insert(key(1), &addrs(2)));
        assert_eq!(cf.len(), 1);
    }

    #[test]
    fn delete_removes_and_allows_reinsert() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(3));
        assert!(cf.delete(key(1)));
        assert!(!cf.contains(key(1)));
        assert!(!cf.delete(key(1)), "double delete fails");
        assert!(cf.insert(key(1), &addrs(2)));
        let hit = cf.lookup(key(1)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 2);
    }

    #[test]
    fn delete_reclaims_arena_blocks() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(40)); // 3 blocks at BLOCK_CAP = 14
        let high_water = cf.arena().blocks_allocated();
        assert!(cf.delete(key(1)));
        assert_eq!(cf.arena().blocks_in_use(), 0, "blocks reclaimed");
        cf.insert(key(2), &addrs(40));
        assert_eq!(
            cf.arena().blocks_allocated(),
            high_water,
            "reinsert reuses freed blocks"
        );
    }

    #[test]
    fn insert_delete_churn_keeps_arena_bounded() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            ..CuckooConfig::default()
        });
        for cycle in 0..200u64 {
            for i in 0..50 {
                assert!(cf.insert(key(cycle * 50 + i), &addrs(3)));
            }
            for i in 0..50 {
                assert!(cf.delete(key(cycle * 50 + i)));
            }
        }
        assert_eq!(cf.len(), 0);
        assert_eq!(cf.arena().blocks_in_use(), 0);
        assert!(
            cf.arena().blocks_allocated() <= 64,
            "arena grew without bound: {}",
            cf.arena().blocks_allocated()
        );
    }

    #[test]
    fn temperature_bumps_on_lookup() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(1));
        assert_eq!(cf.temperature(key(1)), Some(0));
        cf.lookup(key(1));
        cf.lookup(key(1));
        assert_eq!(cf.temperature(key(1)), Some(2));
    }

    #[test]
    fn lookup_shared_matches_lookup() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(4));
        let via_shared = cf.lookup_shared(key(1)).expect("hit");
        assert_eq!(cf.addresses(via_shared), addrs(4));
        assert_eq!(cf.temperature(key(1)), Some(1), "shared lookup bumps temp");
        assert!(cf.lookup_shared(key(9)).is_none());
        assert_eq!(cf.stats().lookups, 2);
    }

    #[test]
    fn no_false_negatives_at_high_load() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 64,
            ..CuckooConfig::default()
        });
        let n = 3000u64;
        for i in 0..n {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
        }
        for i in 0..n {
            assert!(cf.contains(key(i)), "false negative for {i}");
        }
        assert!(cf.stats().expansions > 0, "should have grown");
        assert!(cf.load_factor() <= 1.0);
    }

    #[test]
    fn expansion_preserves_addresses_and_temps() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 16,
            ..CuckooConfig::default()
        });
        cf.insert(key(0), &addrs(7));
        for _ in 0..5 {
            cf.lookup(key(0));
        }
        for i in 1..2000u64 {
            cf.insert(key(i), &addrs(1));
        }
        assert!(cf.stats().expansions >= 1);
        let hit = cf.lookup(key(0)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 7);
        assert_eq!(cf.temperature(key(0)), Some(6));
    }

    #[test]
    fn interleaved_churn_survives_expansions() {
        // Regression for the expand() migration-retry entry loss: grow
        // through several expansions while deleting, then verify every
        // surviving key. Tiny table + deletes maximize retry pressure.
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 2,
            ..CuckooConfig::default()
        });
        let mut live = Vec::new();
        for i in 0..4000u64 {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
            live.push(i);
            if i % 3 == 0 {
                let victim = live.remove((i as usize / 3) % live.len());
                assert!(cf.delete(key(victim)), "delete {victim}");
            }
        }
        assert!(cf.stats().expansions >= 3, "not enough expansions");
        for &i in &live {
            let hit = cf.lookup(key(i));
            assert!(hit.is_some(), "entry {i} lost in migration");
            assert_eq!(cf.addresses(hit.unwrap()), addrs(1));
        }
        assert_eq!(cf.len(), live.len());
    }

    #[test]
    fn push_address_grows_list() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(2));
        assert!(cf.push_address(key(1), EntityAddress::new(9, 9)));
        let hit = cf.lookup(key(1)).unwrap();
        assert_eq!(cf.addresses(hit).len(), 3);
        assert!(!cf.push_address(key(2), EntityAddress::new(0, 0)));
    }

    #[test]
    fn maintain_sorts_hot_entities_front() {
        // Two entities forced into the same bucket: look one up many
        // times; after maintain() it must sit at position 0.
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1, // single bucket: everything collides
            slots: 4,
            load_threshold: 1.0,
            ..CuckooConfig::default()
        });
        let (a, b, c) = (key(10), key(20), key(30));
        cf.insert(a, &addrs(1));
        cf.insert(b, &addrs(1));
        cf.insert(c, &addrs(1));
        for _ in 0..10 {
            cf.lookup(c);
        }
        cf.lookup(a);
        cf.maintain();
        assert_eq!(cf.bucket_position(c), Some(0), "hottest first");
        // colder entities still findable
        assert!(cf.contains(a) && cf.contains(b));
    }

    #[test]
    fn sorting_disabled_is_a_noop() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 1,
            slots: 4,
            load_threshold: 1.0,
            sort_by_temperature: false,
            ..CuckooConfig::default()
        });
        let (a, b) = (key(1), key(2));
        cf.insert(a, &addrs(1));
        cf.insert(b, &addrs(1));
        let before = cf.bucket_position(b);
        for _ in 0..10 {
            cf.lookup(b);
        }
        cf.maintain();
        assert_eq!(cf.bucket_position(b), before, "no reorder when disabled");
    }

    #[test]
    fn load_factor_tracks_len() {
        let mut cf = CuckooFilter::new(CuckooConfig {
            initial_buckets: 256,
            ..CuckooConfig::default()
        });
        for i in 0..512u64 {
            cf.insert(key(i), &[]);
        }
        assert!((cf.load_factor() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn empty_address_list_insert() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &[]);
        let hit = cf.lookup(key(1)).unwrap();
        assert_eq!(hit.head, NIL);
        assert!(cf.addresses(hit).is_empty());
    }

    #[test]
    fn stats_count_probes() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(1));
        cf.lookup(key(1));
        let s = cf.stats();
        assert_eq!(s.lookups, 1);
        assert!(s.slots_probed >= 1);
    }

    #[test]
    fn paper_scale_3148_entities_in_1024_buckets() {
        // §4.5.1: 3,148 entities, 1024 buckets x 4 slots, load 0.7686,
        // and a near-zero error rate.
        let mut cf = CuckooFilter::new(CuckooConfig::default());
        for i in 0..3148u64 {
            assert!(cf.insert(key(i), &addrs(1)));
        }
        assert_eq!(cf.buckets(), 1024, "no expansion needed at 0.77 load");
        let lf = cf.load_factor();
        assert!((lf - 0.7686).abs() < 1e-4, "load factor {lf}");
        // false-positive sweep over foreign keys
        let fp = (10_000..30_000u64).filter(|&i| cf.contains(key(i))).count();
        let rate = fp as f64 / 20_000.0;
        assert!(rate < 0.01, "fp rate {rate}");
    }

    #[test]
    fn hot_bytes_much_smaller_than_total() {
        let mut cf = CuckooFilter::default();
        for i in 0..1000u64 {
            cf.insert(key(i), &addrs(2));
        }
        assert!(cf.hot_bytes() * 4 < cf.memory_bytes());
    }

    #[test]
    fn clone_is_independent() {
        let mut cf = CuckooFilter::default();
        cf.insert(key(1), &addrs(2));
        let mut copy = cf.clone();
        copy.delete(key(1));
        assert!(cf.contains_exact(key(1)), "original unaffected by clone ops");
        assert!(!copy.contains_exact(key(1)));
    }

    #[test]
    fn block_cap_constant_sane() {
        assert!(crate::filter::blocklist::BLOCK_CAP >= 4);
    }
}
