//! Filter micro-benchmarks: insert / lookup / delete ops per second for
//! the improved Cuckoo Filter vs Bloom filter vs std HashMap index —
//! the raw data-structure numbers behind the Table 1/2 system results.
//!
//! Run: `cargo bench --bench filters`. Writes `results/filters.csv` and
//! a machine-readable copy of the same rows to `results/BENCH_filters.json`.

use std::collections::HashMap;

use cft_rag::bench::harness::{bench, print_table};
use cft_rag::filter::bloom::BloomFilter;
use cft_rag::filter::cuckoo::{CuckooConfig, CuckooFilter};
use cft_rag::filter::fingerprint::entity_key;
use cft_rag::forest::EntityAddress;
use cft_rag::util::cli::{spec, Args};
use cft_rag::util::csv::CsvTable;
use cft_rag::util::json::Json;

fn main() {
    let args = Args::from_env(vec![
        spec("n", "entities", Some("100000"), false),
        spec("repeats", "timed repeats", Some("5"), false),
        spec("out", "CSV output path", Some("results/filters.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let n: usize = args.num_or("n", 100_000);
    let repeats: usize = args.num_or("repeats", 5);

    let keys: Vec<u64> = (0..n)
        .map(|i| entity_key(&format!("entity-{i}")))
        .collect();
    let addr = [EntityAddress::new(0, 0)];

    let mut rows = Vec::new();
    let mut csv = CsvTable::new(&["structure", "op", "mops_per_s"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut emit = |structure: &str, op: &str, secs: f64, ops: usize| {
        let mops = ops as f64 / secs / 1e6;
        rows.push(vec![
            structure.to_string(),
            op.to_string(),
            format!("{mops:.2}"),
        ]);
        csv.push(&[structure.to_string(), op.to_string(), format!("{mops}")]);
        rows_json.push(Json::obj(vec![
            ("structure", Json::Str(structure.to_string())),
            ("op", Json::Str(op.to_string())),
            ("mops_per_s", Json::Num(mops)),
        ]));
    };

    // Cuckoo filter
    {
        let r = bench("cuckoo-insert", 1, repeats, || {
            let mut cf = CuckooFilter::new(CuckooConfig::default());
            for &k in &keys {
                cf.insert(k, &addr);
            }
        });
        emit("cuckoo", "insert", r.summary().p50, n);

        let mut cf = CuckooFilter::new(CuckooConfig::default());
        for &k in &keys {
            cf.insert(k, &addr);
        }
        let r = bench("cuckoo-lookup", 1, repeats, || {
            let mut hits = 0usize;
            for &k in &keys {
                if cf.lookup(k).is_some() {
                    hits += 1;
                }
            }
            assert_eq!(hits, keys.len());
        });
        emit("cuckoo", "lookup-hit", r.summary().p50, n);

        let miss_keys: Vec<u64> = (0..n)
            .map(|i| entity_key(&format!("missing-{i}")))
            .collect();
        let r = bench("cuckoo-lookup-miss", 1, repeats, || {
            let mut hits = 0usize;
            for &k in &miss_keys {
                if cf.contains(k) {
                    hits += 1;
                }
            }
            assert!(hits < n / 50, "fp rate blew up: {hits}");
        });
        emit("cuckoo", "lookup-miss", r.summary().p50, n);

        let r = bench("cuckoo-delete", 1, repeats, || {
            let mut cf2 = cf.clone();
            for &k in &keys {
                cf2.delete(k);
            }
        });
        emit("cuckoo", "delete(+clone)", r.summary().p50, n);
    }

    // Bloom filter
    {
        let r = bench("bloom-insert", 1, repeats, || {
            let mut bf = BloomFilter::new(n, 0.01);
            for &k in &keys {
                bf.insert(k);
            }
        });
        emit("bloom", "insert", r.summary().p50, n);

        let mut bf = BloomFilter::new(n, 0.01);
        for &k in &keys {
            bf.insert(k);
        }
        let r = bench("bloom-lookup", 1, repeats, || {
            let mut hits = 0usize;
            for &k in &keys {
                if bf.contains(k) {
                    hits += 1;
                }
            }
            assert_eq!(hits, keys.len());
        });
        emit("bloom", "lookup-hit", r.summary().p50, n);
    }

    // HashMap direct index (upper-bound comparator)
    {
        let r = bench("hashmap-insert", 1, repeats, || {
            let mut m: HashMap<u64, Vec<EntityAddress>> = HashMap::new();
            for &k in &keys {
                m.insert(k, addr.to_vec());
            }
        });
        emit("hashmap", "insert", r.summary().p50, n);

        let mut m: HashMap<u64, Vec<EntityAddress>> = HashMap::new();
        for &k in &keys {
            m.insert(k, addr.to_vec());
        }
        let r = bench("hashmap-lookup", 1, repeats, || {
            let mut hits = 0usize;
            for &k in &keys {
                if m.contains_key(&k) {
                    hits += 1;
                }
            }
            assert_eq!(hits, keys.len());
        });
        emit("hashmap", "lookup-hit", r.summary().p50, n);
    }

    print_table(
        &format!("Filter micro-benchmarks ({n} keys)"),
        &["structure", "op", "Mops/s"],
        &rows,
    );
    let out = args.str_or("out", "results/filters.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");

    let bench_json = Json::obj(vec![
        ("bench", Json::Str("filters".to_string())),
        ("keys", Json::Num(n as f64)),
        ("rows", Json::Arr(rows_json)),
    ]);
    let json_out = match out.rfind('/') {
        Some(i) => format!("{}/BENCH_filters.json", &out[..i]),
        None => "BENCH_filters.json".to_string(),
    };
    std::fs::write(&json_out, format!("{bench_json}\n"))
        .expect("write bench json");
    println!("wrote {json_out}");
}
