//! Crate-wide error type.

use thiserror::Error;

/// All failure modes of the CFT-RAG stack.
#[derive(Debug, Error)]
pub enum CftError {
    /// Artifact loading / manifest problems (run `make artifacts`).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Bad request or configuration.
    #[error("config error: {0}")]
    Config(String),

    /// Coordinator lifecycle problems (channel closed, worker died).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// I/O.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for CftError {
    fn from(e: xla::Error) -> Self {
        CftError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, CftError>;
