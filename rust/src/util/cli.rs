//! Minimal command-line flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and typed getters with defaults. Each binary declares its
//! flags up front so `--help` can print a usage table.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared flag (for help text and validation).
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_bool: bool,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positional: Vec<String>,
    specs: Vec<FlagSpec>,
}

impl Args {
    /// Declare flags (used for help/validation), then parse `argv`.
    pub fn parse_with(
        argv: impl IntoIterator<Item = String>,
        specs: Vec<FlagSpec>,
    ) -> Result<Args, String> {
        let mut out = Args { specs, ..Default::default() };
        let bool_names: Vec<&str> = out
            .specs
            .iter()
            .filter(|s| s.is_bool)
            .map(|s| s.name)
            .collect();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_names.contains(&stripped) {
                    out.bools.push(stripped.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        // flag with no value: treat as boolean anyway
                        out.bools.push(stripped.to_string());
                    } else {
                        out.flags.insert(stripped.to_string(), it.next().unwrap());
                    }
                } else {
                    out.bools.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        // validate that provided flags were declared (if any specs given)
        if !out.specs.is_empty() {
            let known: Vec<&str> = out.specs.iter().map(|s| s.name).collect();
            for k in out.flags.keys().chain(out.bools.iter()) {
                if !known.contains(&k.as_str()) && k != "help" {
                    return Err(format!("unknown flag --{k}\n{}", out.usage()));
                }
            }
        }
        Ok(out)
    }

    /// Parse from the process's actual argv (skipping the binary name).
    pub fn from_env(specs: Vec<FlagSpec>) -> Result<Args, String> {
        Self::parse_with(std::env::args().skip(1), specs)
    }

    /// True if `--help` was requested.
    pub fn wants_help(&self) -> bool {
        self.bools.iter().any(|b| b == "help") || self.flags.contains_key("help")
    }

    /// Usage string built from the declared specs.
    pub fn usage(&self) -> String {
        let mut s = String::from("flags:\n");
        for spec in &self.specs {
            let d = spec
                .default
                .map(|d| format!(" (default {d})"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{:<18} {}{}", spec.name, spec.help, d);
        }
        s
    }

    /// Raw string flag value (or declared default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .get(name)
            .map(|s| s.as_str())
            .or_else(|| {
                self.specs
                    .iter()
                    .find(|s| s.name == name)
                    .and_then(|s| s.default)
            })
    }

    /// String flag with explicit fallback.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed flag parse with explicit fallback.
    pub fn num_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Boolean flag (present => true).
    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
            || matches!(self.flags.get(name).map(|s| s.as_str()), Some("true" | "1"))
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list flag parsed to numbers.
    pub fn list_or<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }

    /// Comma-separated string-list flag (e.g. `--backends a:1,b:2` for
    /// the router); entries are trimmed, empties dropped.
    pub fn strs_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Convenience macro-free spec builder.
pub fn spec(
    name: &'static str,
    help: &'static str,
    default: Option<&'static str>,
    is_bool: bool,
) -> FlagSpec {
    FlagSpec { name, help, default, is_bool }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], specs: Vec<FlagSpec>) -> Args {
        Args::parse_with(args.iter().map(|s| s.to_string()), specs).unwrap()
    }

    #[test]
    fn parses_eq_and_space_forms() {
        let a = parse(
            &["--trees=300", "--entities", "5"],
            vec![
                spec("trees", "", None, false),
                spec("entities", "", None, false),
            ],
        );
        assert_eq!(a.num_or("trees", 0usize), 300);
        assert_eq!(a.num_or("entities", 0usize), 5);
    }

    #[test]
    fn bool_flags() {
        let a = parse(
            &["--verbose", "--trees", "10"],
            vec![
                spec("verbose", "", None, true),
                spec("trees", "", None, false),
            ],
        );
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.num_or("trees", 0usize), 10);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], vec![spec("out", "", Some("results.csv"), false)]);
        assert_eq!(a.str_or("out", "x"), "results.csv");
        assert_eq!(a.num_or("missing", 7u32), 7);
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["serve", "--port", "9000"], vec![spec("port", "", None, false)]);
        assert_eq!(a.positional(), &["serve".to_string()]);
    }

    #[test]
    fn unknown_flag_rejected() {
        let r = Args::parse_with(
            ["--bogus".to_string()],
            vec![spec("real", "", None, false)],
        );
        assert!(r.is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse(&["--sizes", "50,300,600"], vec![spec("sizes", "", None, false)]);
        assert_eq!(a.list_or("sizes", &[1usize]), vec![50, 300, 600]);
        assert_eq!(a.list_or("other", &[1usize, 2]), vec![1, 2]);
    }

    #[test]
    fn string_list_flag() {
        let a = parse(
            &["--backends", "127.0.0.1:7171, 127.0.0.1:7172,"],
            vec![spec("backends", "", None, false)],
        );
        assert_eq!(
            a.strs_or("backends", &[]),
            vec!["127.0.0.1:7171".to_string(), "127.0.0.1:7172".to_string()]
        );
        assert_eq!(a.strs_or("missing", &["x"]), vec!["x".to_string()]);
        assert!(a.strs_or("missing", &[]).is_empty());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse(
            &["--trees", "5", "--sort"],
            vec![spec("trees", "", None, false), spec("sort", "", None, true)],
        );
        assert!(a.flag("sort"));
    }
}
