//! The CFT-RAG pipeline — Figure 1 end to end:
//!
//! query → vector search (score artifact) → entity recognition
//! (gazetteer NER) → tree retrieval (configured algorithm) → context
//! generation (Algorithm 3) → prompt assembly → answer generation
//! (rank artifact) → optional judging.

use std::sync::Arc;
use std::time::Duration;

use crate::data::corpus::Document;
use crate::error::Result;
use crate::forest::Forest;
use crate::llm::generator::{Answer, Generator};
use crate::llm::prompt::Prompt;
use crate::nlp::ner::GazetteerNer;
use crate::rag::config::{Algorithm, RagConfig};
use crate::retrieval::bloom2_rag::Bloom2TRag;
use crate::retrieval::bloom_rag::BloomTRag;
use crate::retrieval::context::{generate_context, Context};
use crate::retrieval::cuckoo_rag::CuckooTRag;
use crate::retrieval::naive::NaiveTRag;
use crate::retrieval::sharded_rag::ShardedCuckooTRag;
use crate::retrieval::{
    ArcRetriever, ConcurrentRetriever, MutexRetriever, Retriever,
};
use crate::runtime::engine::Engine;
use crate::text::tokenizer::tokenize_padded;
use crate::util::stats::Timer;
use crate::vector::{search_topk, VectorStore};

/// Build the configured retriever for a forest (single-threaded use:
/// benches and the in-process pipeline). `cfg.shards > 1` selects the
/// shard-partitioned Cuckoo filter; 0/1 keep the classic unsharded one,
/// whose probe statistics the Figure-5 reproduction reads.
///
/// A configured [`RagConfig::key_partition`] is enforced here, at
/// index-build time: the Cuckoo retrievers index only the keys whose
/// replica set contains this backend. The Bloom/naive baselines cannot
/// partition (their annotations are whole-tree), which
/// [`RagConfig::validate`] rejects — reaching them here with a
/// partition set only logs, for callers that skip validation.
pub fn make_retriever(
    forest: Arc<Forest>,
    cfg: &RagConfig,
) -> Box<dyn Retriever + Send> {
    if cfg.key_partition.is_some() && cfg.algorithm != Algorithm::Cuckoo {
        crate::util::log::warn!(
            "key partition is only enforced by the Cuckoo retrievers; \
             {} will index the full forest",
            cfg.algorithm.label()
        );
    }
    match cfg.algorithm {
        Algorithm::Naive => Box::new(NaiveTRag::new(forest)),
        Algorithm::Bloom => Box::new(BloomTRag::new(forest, cfg.bloom_fp_rate)),
        Algorithm::Bloom2 => Box::new(Bloom2TRag::new(forest, cfg.bloom_fp_rate)),
        Algorithm::Cuckoo if cfg.shards > 1 => {
            Box::new(ShardedCuckooTRag::with_partition(
                forest,
                cfg.cuckoo,
                cfg.shards,
                cfg.key_partition.clone(),
            ))
        }
        Algorithm::Cuckoo => Box::new(CuckooTRag::with_partition(
            forest,
            cfg.cuckoo,
            cfg.key_partition.clone(),
        )),
    }
}

/// Build the configured retriever for the **concurrent** serving path
/// (the coordinator's worker pool). The Cuckoo algorithm gets the
/// shard-parallel retriever — `cfg.shards == 0` auto-sizes to the
/// machine — so worker threads retrieve under per-shard read locks,
/// honoring [`RagConfig::key_partition`] exactly like [`make_retriever`]
/// (a partitioned serving backend indexes only its owned keys). The
/// Bloom baselines' annotations are read-only after build, so they are
/// shared lock-free as `Arc`s ([`ArcRetriever`]) — honest concurrent
/// baselines for the router/coordinator throughput comparisons — and
/// only the index-free naive scan still serializes through a mutex.
pub fn make_concurrent_retriever(
    forest: Arc<Forest>,
    cfg: &RagConfig,
) -> Arc<dyn ConcurrentRetriever> {
    match cfg.algorithm {
        Algorithm::Cuckoo => Arc::new(ShardedCuckooTRag::with_partition(
            forest,
            cfg.cuckoo,
            cfg.resolved_shards(),
            cfg.key_partition.clone(),
        )),
        Algorithm::Bloom => Arc::new(ArcRetriever::new(BloomTRag::new(
            forest,
            cfg.bloom_fp_rate,
        ))),
        Algorithm::Bloom2 => Arc::new(ArcRetriever::new(Bloom2TRag::new(
            forest,
            cfg.bloom_fp_rate,
        ))),
        Algorithm::Naive => {
            Arc::new(MutexRetriever::new(make_retriever(forest, cfg)))
        }
    }
}

/// Response of one pipeline run.
#[derive(Clone, Debug)]
pub struct RagResponse {
    pub answer: Answer,
    pub entities: Vec<String>,
    pub context: Context,
    pub retrieved_docs: Vec<u32>,
    /// Tree-retrieval stage wall time (the paper's measured quantity).
    pub retrieval_time: Duration,
    /// Whole-pipeline wall time.
    pub total_time: Duration,
}

/// The assembled pipeline.
pub struct RagPipeline {
    forest: Arc<Forest>,
    engine: Arc<dyn Engine>,
    store: VectorStore,
    ner: GazetteerNer,
    retriever: Box<dyn Retriever + Send>,
    cfg: RagConfig,
}

impl RagPipeline {
    /// Build every stage: embeds the corpus, annotates/indexes the
    /// forest per the configured algorithm, prepares the gazetteer.
    pub fn build(
        forest: Arc<Forest>,
        documents: Vec<Document>,
        engine: Arc<dyn Engine>,
        cfg: RagConfig,
    ) -> Result<RagPipeline> {
        let store = VectorStore::build(engine.as_ref(), documents)?;
        let ner = GazetteerNer::new(forest.interner().iter().map(|(_, n)| n));
        let retriever = make_retriever(forest.clone(), &cfg);
        Ok(RagPipeline { forest, engine, store, ner, retriever, cfg })
    }

    /// The configured algorithm.
    pub fn algorithm(&self) -> Algorithm {
        self.cfg.algorithm
    }

    /// The forest.
    pub fn forest(&self) -> &Arc<Forest> {
        &self.forest
    }

    /// Answer one query end to end.
    pub fn answer(&mut self, query: &str) -> Result<RagResponse> {
        let total = Timer::start();
        let shape = self.engine.shape();

        // 1. vector search
        let mut qtoks = vec![0i32; shape.batch * shape.max_tokens];
        qtoks[..shape.max_tokens]
            .copy_from_slice(&tokenize_padded(query, shape.max_tokens));
        let qemb = self.engine.embed(&qtoks)?;
        let retrieved_docs: Vec<u32> = if self.store.is_empty() {
            Vec::new()
        } else {
            search_topk(
                self.engine.as_ref(),
                &self.store,
                &qemb,
                1,
                self.cfg.topk_docs,
            )?[0]
                .iter()
                .map(|h| h.doc)
                .collect()
        };

        // 2. entity recognition
        let entities = self.ner.recognize(query);

        // 3 + 4. tree retrieval + context generation (timed: the paper's
        // reported "retrieval time" is exactly this stage)
        let rt = Timer::start();
        let mut context = Context::default();
        for e in &entities {
            let addrs = self.retriever.find(e);
            context.merge(generate_context(
                &self.forest,
                e,
                &addrs,
                self.cfg.context_levels,
            ));
        }
        let retrieval_time = rt.elapsed();

        // 5. prompt assembly
        let docs_text: Vec<String> = retrieved_docs
            .iter()
            .map(|&d| self.store.doc(d).body.clone())
            .collect();
        let prompt = Prompt::assemble(docs_text, &context, query);

        // 6. generation
        let generator = Generator::new(self.engine.as_ref());
        let answer = generator.generate(query, &context, &prompt)?;

        Ok(RagResponse {
            answer,
            entities,
            context,
            retrieved_docs,
            retrieval_time,
            total_time: total.elapsed(),
        })
    }

    /// End-of-round maintenance (CF temperature sorting).
    pub fn maintain(&mut self) {
        self.retriever.maintain();
    }

    /// Dynamic knowledge update (paper §5: "ongoing data update"):
    /// ingest a raw document at serve time — extract relations (§2.2),
    /// filter them (§2.3), grow the forest with the new tree(s), refresh
    /// the retriever index (incremental for the Cuckoo retriever, rebuild
    /// for the Bloom baselines), extend the NER gazetteer, and embed the
    /// document into the vector store. Returns the new tree indices.
    pub fn add_document(&mut self, text: &str) -> Result<Vec<u32>> {
        let pairs = crate::nlp::relate::extract_pairs(text);
        let filtered = crate::nlp::filter::filter_relations(&pairs);

        let mut grown = (*self.forest).clone();
        let new_trees = crate::forest::builder::build_trees(&mut grown, &filtered);
        let grown = Arc::new(grown);

        self.retriever.reindex(grown.clone(), &new_trees);
        self.forest = grown;
        self.ner = GazetteerNer::new(self.forest.interner().iter().map(|(_, n)| n));

        let doc = crate::data::corpus::corpus_from_texts(&[text.to_string()])
            .pop()
            .expect("one document");
        self.store.push(self.engine.as_ref(), doc)?;
        Ok(new_trees)
    }

    /// Direct access to the retriever (benches).
    pub fn retriever_mut(&mut self) -> &mut (dyn Retriever + Send) {
        self.retriever.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::corpus_from_texts;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};
    use crate::llm::judge::judge;
    use crate::runtime::engine::NativeEngine;

    fn pipeline(algorithm: Algorithm) -> (RagPipeline, HospitalDataset) {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 8,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let cfg = RagConfig { algorithm, ..RagConfig::default() };
        (RagPipeline::build(forest, docs, engine, cfg).unwrap(), ds)
    }

    #[test]
    fn answers_mention_parent() {
        let (mut p, _ds) = pipeline(Algorithm::Cuckoo);
        let resp = p.answer("where does cardiology sit in the organization").unwrap();
        assert!(resp.entities.contains(&"cardiology".to_string()));
        assert!(!resp.context.is_empty());
        assert!(resp.answer.text.contains("cardiology"));
    }

    #[test]
    fn all_algorithms_same_context_set() {
        let mut contexts = Vec::new();
        for alg in Algorithm::ALL {
            let (mut p, _) = pipeline(alg);
            let resp = p.answer("describe the hierarchy around cardiology").unwrap();
            let mut rel: Vec<String> =
                resp.context.related_set().into_iter().collect();
            rel.sort();
            contexts.push(rel);
        }
        assert_eq!(contexts[0], contexts[1]);
        assert_eq!(contexts[0], contexts[2]);
        assert_eq!(contexts[0], contexts[3]);
    }

    #[test]
    fn judged_accuracy_reasonable() {
        use crate::data::workload::{Workload, WorkloadConfig};
        let (mut p, ds) = pipeline(Algorithm::Cuckoo);
        let forest = ds.build_forest();
        let w = Workload::generate(
            &forest,
            WorkloadConfig { queries: 10, ..Default::default() },
        );
        let mut total = crate::llm::judge::Judgement::default();
        for q in &w.queries {
            let resp = p.answer(&q.text).unwrap();
            total.merge(judge(&resp.answer.text, &q.gold));
        }
        let acc = total.accuracy();
        assert!(acc > 0.3 && acc <= 1.0, "accuracy {acc}");
    }

    #[test]
    fn add_document_makes_new_knowledge_answerable() {
        for alg in Algorithm::ALL {
            let (mut p, _) = pipeline(alg);
            // unknown before
            let before = p.answer("where does the lunar clinic sit in the organization").unwrap();
            assert!(before.context.is_empty(), "{}", alg.label());
            // ingest a document introducing the entity
            let new_trees = p
                .add_document(
                    "The lunar clinic belongs to Starlight Hospital. \
                     The gravity ward belongs to the lunar clinic.",
                )
                .unwrap();
            assert!(!new_trees.is_empty());
            // answerable after, via the same pipeline instance
            let after = p.answer("where does the lunar clinic sit in the organization").unwrap();
            assert!(
                after.entities.contains(&"lunar clinic".to_string()),
                "{}: {:?}",
                alg.label(),
                after.entities
            );
            assert!(after.answer.text.contains("starlight hospital"), "{}", alg.label());
            assert!(after.answer.text.contains("gravity ward"), "{}", alg.label());
        }
    }

    #[test]
    fn incremental_cuckoo_reindex_matches_fresh_rebuild() {
        use crate::retrieval::cuckoo_rag::CuckooTRag;
        use crate::retrieval::Retriever;
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let base = Arc::new(ds.build_forest());
        let mut incremental = CuckooTRag::new(base.clone());

        // grow the forest by two documents
        let mut grown = (*base).clone();
        let t1 = crate::forest::builder::build_trees(
            &mut grown,
            &[("cardiology".into(), "nova hospital".into())],
        );
        let t2 = crate::forest::builder::build_trees(
            &mut grown,
            &[("flux ward".into(), "nova hospital".into())],
        );
        let grown = Arc::new(grown);
        let new_trees: Vec<u32> = t1.into_iter().chain(t2).collect();
        incremental.reindex(grown.clone(), &new_trees);

        let mut fresh = CuckooTRag::new(grown.clone());
        for (_, name) in grown.interner().iter() {
            let mut a = incremental.find(name);
            let mut b = fresh.find(name);
            a.sort();
            b.sort();
            assert_eq!(a, b, "{name}");
        }
    }

    #[test]
    fn sharded_pipeline_matches_unsharded_context() {
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 8,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let docs = corpus_from_texts(&ds.documents());
        let mut contexts = Vec::new();
        for shards in [1usize, 4] {
            let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
            let cfg = RagConfig { shards, ..RagConfig::default() };
            let mut p =
                RagPipeline::build(forest.clone(), docs.clone(), engine, cfg)
                    .unwrap();
            let resp = p.answer("describe the hierarchy around cardiology").unwrap();
            let mut rel: Vec<String> =
                resp.context.related_set().into_iter().collect();
            rel.sort();
            contexts.push(rel);
        }
        assert_eq!(contexts[0], contexts[1], "sharding must not change results");
    }

    #[test]
    fn concurrent_retriever_finds_and_reindexes() {
        use crate::retrieval::sharded_rag::ShardedCuckooTRag;
        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let base = Arc::new(ds.build_forest());
        let r = make_concurrent_retriever(base.clone(), &RagConfig::default());
        let mut out = Vec::new();
        r.find_concurrent("cardiology", &mut out);
        assert!(!out.is_empty());

        // incremental reindex through the concurrent interface
        let mut grown = (*base).clone();
        let new_trees = crate::forest::builder::build_trees(
            &mut grown,
            &[("flux ward".into(), "nova hospital".into())],
        );
        let grown = Arc::new(grown);
        r.reindex_concurrent(grown.clone(), &new_trees);
        out.clear();
        r.find_concurrent("flux ward", &mut out);
        assert_eq!(out.len(), 1);

        // matches a fresh sharded build over the grown forest
        let fresh = ShardedCuckooTRag::new(grown.clone(), 4);
        for (_, name) in grown.interner().iter() {
            let mut a = Vec::new();
            let mut b = Vec::new();
            r.find_concurrent(name, &mut a);
            fresh.find_concurrent(name, &mut b);
            a.sort();
            b.sort();
            assert_eq!(a, b, "{name}");
        }
        assert!(r.index_bytes() > 0);
    }

    #[test]
    fn partitioned_retrievers_cover_each_key_exactly_r_times() {
        use crate::rag::config::KeyPartition;

        let ds = HospitalDataset::generate(HospitalConfig {
            trees: 6,
            ..HospitalConfig::default()
        });
        let forest = Arc::new(ds.build_forest());
        let addrs = ["10.0.0.1:7171", "10.0.0.2:7171", "10.0.0.3:7171"];
        for r in [1usize, 2] {
            // one partitioned retriever per fleet position, both shard
            // configurations (unsharded CuckooTRag and the sharded one)
            for shards in [1usize, 4] {
                let mut retrievers: Vec<Box<dyn Retriever + Send>> = (0
                    ..addrs.len())
                    .map(|i| {
                        let cfg = RagConfig {
                            shards,
                            replication_factor: r,
                            key_partition: Some(
                                KeyPartition::new(addrs, i, r).unwrap(),
                            ),
                            ..RagConfig::default()
                        };
                        cfg.validate().unwrap();
                        make_retriever(forest.clone(), &cfg)
                    })
                    .collect();
                for (_, name) in forest.interner().iter() {
                    let holders: usize = retrievers
                        .iter_mut()
                        .map(|rt| usize::from(!rt.find(name).is_empty()))
                        .sum();
                    assert_eq!(
                        holders, r,
                        "{name}: {holders} holders at R={r}, shards={shards}"
                    );
                }
            }
            // the concurrent serving path enforces the same partition
            let concurrent: Vec<Arc<dyn ConcurrentRetriever>> = (0
                ..addrs.len())
                .map(|i| {
                    let cfg = RagConfig {
                        shards: 2,
                        replication_factor: r,
                        key_partition: Some(
                            KeyPartition::new(addrs, i, r).unwrap(),
                        ),
                        ..RagConfig::default()
                    };
                    make_concurrent_retriever(forest.clone(), &cfg)
                })
                .collect();
            let mut out = Vec::new();
            for (_, name) in forest.interner().iter() {
                let holders = concurrent
                    .iter()
                    .filter(|rt| {
                        out.clear();
                        rt.find_concurrent(name, &mut out);
                        !out.is_empty()
                    })
                    .count();
                assert_eq!(holders, r, "{name} (concurrent) at R={r}");
            }
        }
    }

    #[test]
    fn unknown_entities_yield_graceful_answer() {
        let (mut p, _) = pipeline(Algorithm::Cuckoo);
        let resp = p.answer("what about the quantum flux capacitor").unwrap();
        assert!(resp.context.is_empty());
        assert!(resp.answer.text.contains("No hierarchy information"));
    }
}
