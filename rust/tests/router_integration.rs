//! Shard-router integration over REAL in-process TCP backends: each
//! backend is a full coordinator (batcher, workers, maintainer) behind
//! `coordinator/tcp.rs`, started with `serve_with_shutdown` so tests
//! can kill and restart backends without leaking listeners — the
//! graceful-shutdown satellite of PR 3 exercised end to end.

use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use cft_rag::coordinator::tcp::{serve_with_shutdown, ServeHandle};
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::filter::fingerprint::entity_key;
use cft_rag::rag::config::{RagConfig, RouterConfig};
use cft_rag::router::Router;
use cft_rag::runtime::engine::{Engine, NativeEngine};
use cft_rag::util::json::Json;

/// One in-process backend: a coordinator behind a real TCP listener.
struct TestBackend {
    coordinator: Arc<Coordinator>,
    handle: Option<ServeHandle>,
    addr: String,
}

impl TestBackend {
    fn start(ds: &HospitalDataset, addr: &str) -> TestBackend {
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let coordinator = Arc::new(
            Coordinator::start(
                forest,
                corpus_from_texts(&ds.documents()),
                engine,
                RagConfig::default(),
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .expect("backend coordinator"),
        );
        let handle = serve_with_shutdown(coordinator.clone(), addr)
            .expect("backend listener");
        let addr = handle.addr().to_string();
        TestBackend { coordinator, handle: Some(handle), addr }
    }

    /// Hard stop: listener down, coordinator drained and joined.
    fn kill(&mut self) {
        if let Some(h) = self.handle.take() {
            h.shutdown();
        }
        self.coordinator.stop();
    }
}

impl Drop for TestBackend {
    fn drop(&mut self) {
        self.kill();
    }
}

fn dataset(trees: usize) -> HospitalDataset {
    HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    })
}

fn entity_names(ds: &HospitalDataset) -> Vec<String> {
    ds.build_forest()
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect()
}

fn cluster(
    ds: &HospitalDataset,
    n: usize,
    cfg: &RouterConfig,
) -> (Vec<TestBackend>, Arc<Router>) {
    let backends: Vec<TestBackend> =
        (0..n).map(|_| TestBackend::start(ds, "127.0.0.1:0")).collect();
    let cfg = RouterConfig {
        backends: backends.iter().map(|b| b.addr.clone()).collect(),
        ..cfg.clone()
    };
    let names = entity_names(ds);
    let router = Arc::new(
        Router::connect(names.iter().map(String::as_str), &cfg)
            .expect("router"),
    );
    (backends, router)
}

/// Deterministic-traffic config: no background prober.
fn quiet_cfg() -> RouterConfig {
    RouterConfig {
        probe_interval: Duration::ZERO,
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_secs(10),
        ..RouterConfig::default()
    }
}

fn is_ok(reply: &Json) -> bool {
    reply.get("ok") == Some(&Json::Bool(true))
}

#[test]
fn single_entity_queries_route_deterministically() {
    let ds = dataset(4);
    let (_backends, router) = cluster(&ds, 4, &quiet_cfg());
    for _ in 0..3 {
        let reply = router.query("what is the parent unit of cardiology");
        assert!(is_ok(&reply), "{reply}");
        assert_eq!(reply.get("backends").and_then(Json::as_f64), Some(1.0));
        assert!(reply
            .get("entities")
            .and_then(Json::as_arr)
            .is_some_and(|e| !e.is_empty()));
    }
    // all three identical queries landed on the one owning backend
    let snap = router.snapshot();
    let loads: Vec<u64> = snap.backends.iter().map(|b| b.requests).collect();
    assert_eq!(loads.iter().sum::<u64>(), 3, "{loads:?}");
    assert_eq!(loads.iter().filter(|&&r| r > 0).count(), 1, "{loads:?}");
    let owner = router.ring().owner(entity_key("cardiology")).unwrap();
    assert!(loads[owner] == 3, "owner {owner} should serve all: {loads:?}");
}

#[test]
fn multi_owner_queries_scatter_and_merge() {
    let ds = dataset(6);
    let (_backends, router) = cluster(&ds, 4, &quiet_cfg());
    // pick entities until they span at least two owners (which exact
    // names spread where depends only on stable hashes, so walk the
    // vocabulary instead of hard-coding hash outcomes)
    let names = entity_names(&ds);
    let mut picked: Vec<&str> = Vec::new();
    let mut owners = std::collections::BTreeSet::new();
    for n in &names {
        picked.push(n);
        owners.insert(router.ring().owner(entity_key(n)).unwrap());
        if owners.len() >= 2 && picked.len() >= 3 {
            break;
        }
    }
    assert!(owners.len() >= 2, "vocabulary spans one owner only?");
    let query = format!("describe the hierarchy around {}", picked.join(" and "));
    let reply = router.query(&query);
    assert!(is_ok(&reply), "{reply}");
    assert_eq!(
        reply.get("backends").and_then(Json::as_f64),
        Some(owners.len() as f64),
        "one portion per owner: {reply}"
    );
    assert_eq!(reply.get("degraded"), Some(&Json::Bool(false)));
    let merged: Vec<&str> = reply
        .get("entities")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    for p in &picked {
        assert!(merged.contains(p), "{p} missing from merged {merged:?}");
    }
    assert!(router.snapshot().fanouts >= 1);
}

#[test]
fn killing_one_backend_mid_load_fails_zero_queries() {
    let ds = dataset(6);
    let (mut backends, router) = cluster(&ds, 3, &quiet_cfg());
    let names = entity_names(&ds);
    let queries: Vec<String> = names
        .iter()
        .take(24)
        .map(|n| format!("where does {n} sit in the organization"))
        .collect();

    const CLIENTS: usize = 4;
    const PHASE1: usize = 5;
    const PHASE2: usize = 20;
    let mid_load = Arc::new(Barrier::new(CLIENTS + 1));
    let failures = Mutex::new(Vec::<String>::new());

    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let router = router.clone();
            let mid_load = mid_load.clone();
            let queries = &queries;
            let failures = &failures;
            s.spawn(move || {
                let mut serve = |i: usize| {
                    let q = &queries[(c * 7 + i) % queries.len()];
                    let reply = router.query(q);
                    if !is_ok(&reply) {
                        failures.lock().unwrap().push(reply.to_string());
                    }
                };
                for i in 0..PHASE1 {
                    serve(i);
                }
                // all clients are mid-load when the kill happens; they
                // keep querying while backend 0 goes down
                mid_load.wait();
                for i in PHASE1..PHASE1 + PHASE2 {
                    serve(i);
                }
            });
        }
        mid_load.wait();
        backends[0].kill();
    });

    let failed = failures.into_inner().unwrap();
    assert!(
        failed.is_empty(),
        "{} queries failed despite failover: {:?}",
        failed.len(),
        failed.first()
    );
    let snap = router.snapshot();
    assert_eq!(snap.requests, (CLIENTS * (PHASE1 + PHASE2)) as u64);
    assert_eq!(snap.failures, 0);

    // a key owned by the dead backend must still get a non-error reply,
    // served by a failover candidate
    if let Some(victim) = names
        .iter()
        .find(|n| router.ring().owner(entity_key(n.as_str())) == Some(0))
    {
        let before = router.snapshot().failovers;
        let reply = router.query(&format!("tell me about {victim}"));
        assert!(is_ok(&reply), "{reply}");
        assert!(
            router.snapshot().failovers > before,
            "dead owner must be failed over"
        );
    }
}

#[test]
fn prober_observes_load_and_readmits_restarted_backend() {
    let ds = dataset(4);
    let cfg = RouterConfig {
        probe_interval: Duration::from_millis(40),
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_secs(10),
        ..RouterConfig::default()
    };
    let (mut backends, router) = cluster(&ds, 2, &cfg);

    // real queries raise the backend-side request counters; the prober
    // reads them through the \x01stats control line
    for _ in 0..3 {
        assert!(is_ok(&router.query("describe the hierarchy around cardiology")));
    }
    // poll-wait with a fresh deadline per phase (CI can be slow)
    fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting: {what}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }
    let observed = |router: &Router| -> u64 {
        router
            .backends()
            .iter()
            .map(|b| b.health().observed_load())
            .sum()
    };
    wait_until("prober sees the backend load", || observed(&router) >= 3);
    assert!(router.backends().iter().all(|b| b.health().probes() > 0));

    // kill backend 0: the prober demotes it without any query traffic
    let addr = backends[0].addr.clone();
    backends[0].kill();
    wait_until("prober demotes the dead backend", || {
        !router.backends()[0].health().is_healthy()
    });

    // restart on the same port: the prober re-admits automatically
    backends[0] = TestBackend::start(&ds, &addr);
    wait_until("prober re-admits the recovered backend", || {
        router.backends()[0].health().is_healthy()
    });
    assert!(router.backends()[0].health().readmissions() >= 1);
    // and the fleet serves as before
    assert!(is_ok(&router.query("what is the parent unit of oncology")));
}
