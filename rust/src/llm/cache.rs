//! Shared fact-embedding cache for the generator.
//!
//! Query workloads have Zipf locality (the same hot entities — thus the
//! same context-fact sentences — recur across requests), so the
//! generator's per-sentence embeddings are highly re-usable. The cache
//! keys on the FNV hash of the sentence and stores the `[embed_dim]`
//! vector; §Perf records the serving-throughput effect.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::rng::fnv1a;

/// Thread-shared sentence-embedding cache with hit/miss counters.
#[derive(Clone, Debug, Default)]
pub struct EmbedCache {
    inner: Arc<Mutex<CacheInner>>,
}

#[derive(Debug, Default)]
struct CacheInner {
    map: HashMap<u64, Arc<Vec<f32>>>,
    hits: u64,
    misses: u64,
}

impl EmbedCache {
    /// New empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Lookup by sentence text.
    pub fn get(&self, sentence: &str) -> Option<Arc<Vec<f32>>> {
        let key = fnv1a(sentence.as_bytes());
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get(&key).cloned() {
            Some(v) => {
                inner.hits += 1;
                Some(v)
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a computed embedding.
    pub fn put(&self, sentence: &str, embedding: Vec<f32>) {
        let key = fnv1a(sentence.as_bytes());
        self.inner
            .lock()
            .unwrap()
            .map
            .insert(key, Arc::new(embedding));
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.hits, inner.misses)
    }

    /// Entries cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = EmbedCache::new();
        assert!(c.get("a sentence").is_none());
        c.put("a sentence", vec![1.0, 2.0]);
        assert_eq!(c.get("a sentence").unwrap().as_slice(), &[1.0, 2.0]);
        let (hits, misses) = c.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn shared_across_clones() {
        let c = EmbedCache::new();
        let c2 = c.clone();
        c2.put("x", vec![0.5]);
        assert!(c.get("x").is_some());
        assert_eq!(c.len(), 1);
    }
}
