//! The nonblocking line-protocol server engine: one reactor thread
//! drives an accept loop plus a per-connection protocol state machine
//! for the `\x01` line protocol (read-buffer → parse line → dispatch
//! → queued write-back), replacing thread-per-connection serving.
//!
//! The engine is protocol-shape generic: anything that answers one
//! request line with one reply line implements [`LineService`] and
//! gets accept, framing, pipelining, back-pressure, idle reaping,
//! connection limits, and clean shutdown for free. The coordinator
//! front door (`coordinator/tcp.rs`) and the router front door
//! (`router/mod.rs`) are the two services.
//!
//! # Connection state machine
//!
//! Per connection the loop keeps an inbound buffer, an outbound
//! buffer, and an `awaiting` flag. Readable bytes accumulate until a
//! `\n`; each complete line is dispatched to the service with a
//! [`Completion`] handle, **one at a time per connection** — further
//! pipelined lines stay buffered until the in-flight reply lands, so
//! replies are written strictly in request order (the ordering
//! guarantee documented in `docs/PROTOCOL.md`). Services may complete
//! synchronously on the reactor thread or hand the completion to
//! another thread (the coordinator's batch workers do); either way
//! the reply is queued and flushed by the loop.
//!
//! # Adversarial clients
//!
//! * **Slowloris** — the idle clock (`idle_timeout`) advances only
//!   when a *complete* line arrives, so dribbling bytes forever never
//!   refreshes it and the connection is reaped on schedule.
//! * **Half-close** — a client may `shutdown(Write)` after its last
//!   line; buffered complete lines are still served and replies
//!   delivered before the server closes. A partial line at EOF is
//!   discarded, never served.
//! * **Overload** — past `max_connections` the acceptor writes one
//!   best-effort `{"ok":false,"error":"overloaded"}` line and drops
//!   the socket without admitting it.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::time::{Duration, Instant};

use crate::reactor::sys::{Event, Interest, Poller, Waker};
use crate::reactor::timer::Timers;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, Mutex};

/// Token of the listening socket (also its re-arm timer).
const TOKEN_LISTENER: u64 = 0;
/// Token of the wakeup socket.
const TOKEN_WAKER: u64 = 1;
/// First connection id; ids are never reused within a server.
const FIRST_CONN: u64 = 2;

/// How long a persistently failing `accept` parks the listener before
/// retrying (transient fd-exhaustion style errors).
const ACCEPT_BACKOFF: Duration = Duration::from_millis(50);

/// Refusal line written (best-effort) to connections over the limit.
const OVERLOADED_LINE: &[u8] = b"{\"ok\":false,\"error\":\"overloaded\"}\n";

/// Error line a [`Completion`] dropped without an answer turns into,
/// so a service bug degrades to a visible protocol error instead of a
/// connection that hangs forever.
const DROPPED_LINE: &str = "{\"ok\":false,\"error\":\"request dropped\"}";

/// A request-line handler. One implementation per front door.
pub trait LineService: Send + Sync {
    /// Serve one complete, trimmed, non-empty request `line`. Answer
    /// through `done` — synchronously on the calling reactor thread
    /// or later from any thread. Dropping `done` unanswered yields a
    /// `request dropped` protocol error.
    ///
    /// `queued` is how long the complete line sat buffered behind the
    /// connection's previous in-flight request before dispatch
    /// (`Duration::ZERO` when it was dispatched on arrival) — the
    /// front-door queueing delay the tracer records as the
    /// `reactor_queue` span.
    fn serve_line(&self, line: &str, queued: Duration, done: Completion);
}

/// What a completed request does to its connection.
#[derive(Debug)]
enum Outcome {
    /// Write this reply line (newline appended if missing), then
    /// resume serving pipelined lines.
    Reply(String),
    /// Drop the connection without replying (stopped coordinator,
    /// `\x01quit`), discarding any buffered pipelined lines.
    Close,
}

/// Completed-request mailbox: services push outcomes from any thread,
/// the reactor loop drains and applies them after each wakeup.
#[derive(Debug)]
struct CompletionQueue {
    items: Mutex<Vec<(u64, Outcome)>>,
    waker: Arc<Waker>,
}

impl CompletionQueue {
    fn push(&self, conn: u64, outcome: Outcome) {
        self.items.lock().unwrap().push((conn, outcome));
        self.waker.wake();
    }

    fn drain(&self) -> Vec<(u64, Outcome)> {
        std::mem::take(&mut *self.items.lock().unwrap())
    }
}

/// The reply handle for one in-flight request line. Exactly one of
/// [`reply`](Completion::reply) / [`close`](Completion::close) should
/// be called; dropping the handle unanswered produces the
/// `request dropped` error reply instead of wedging the connection.
#[derive(Debug)]
pub struct Completion {
    inner: Option<(u64, Arc<CompletionQueue>)>,
}

impl Completion {
    /// Answer the request with `line` (a trailing newline is added if
    /// absent) and let the connection continue.
    pub fn reply(mut self, line: String) {
        if let Some((conn, queue)) = self.inner.take() {
            queue.push(conn, Outcome::Reply(line));
        }
    }

    /// Drop the connection without answering (and discard any
    /// pipelined lines buffered behind this request).
    pub fn close(mut self) {
        if let Some((conn, queue)) = self.inner.take() {
            queue.push(conn, Outcome::Close);
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        if let Some((conn, queue)) = self.inner.take() {
            queue.push(conn, Outcome::Reply(DROPPED_LINE.to_string()));
        }
    }
}

/// Live serving-pressure counters, shared between the reactor loop
/// (writer) and the service's `\x01stats` reply (reader). All relaxed
/// atomics — these are monitoring gauges, not synchronization.
#[derive(Debug, Default)]
pub struct ServerStats {
    open: AtomicU64,
    queue_depth: AtomicU64,
    overloaded: AtomicU64,
    idle_reaped: AtomicU64,
}

impl ServerStats {
    /// Currently admitted connections (gauge).
    pub fn open_connections(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    /// Request lines dispatched to the service and not yet completed
    /// (gauge) — queueing pressure behind the front door.
    pub fn reactor_queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Connections refused at the `max_connections` limit (counter).
    pub fn overloaded_rejects(&self) -> u64 {
        self.overloaded.load(Ordering::Relaxed)
    }

    /// Connections reaped by the idle timeout (counter) — a rising
    /// value under load is the slowloris signature.
    pub fn idle_deadlines_expired(&self) -> u64 {
        self.idle_reaped.load(Ordering::Relaxed)
    }
}

/// Front-door admission and reaping knobs (wired from
/// `RagConfig`/`RouterConfig`; see `docs/OPERATIONS.md`, "Connection
/// limits and timeouts").
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Admitted-connection cap; connections past it get the
    /// `overloaded` refusal. `0` = unlimited.
    pub max_connections: usize,
    /// Reap a connection this long after its last *completed* request
    /// line. Zero disables reaping.
    pub idle_timeout: Duration,
    /// Longest accepted request line; a longer unterminated line gets
    /// a `request line too long` error and the connection is closed.
    pub max_line_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_connections: 4096,
            idle_timeout: Duration::from_secs(60),
            max_line_bytes: 64 * 1024,
        }
    }
}

/// Handle to a running reactor server. Dropping it (or calling
/// [`shutdown`](ServerHandle::shutdown)) stops the loop, closes every
/// connection and the listener, and joins the thread — after which
/// the port is free to rebind.
#[derive(Debug)]
pub struct ServerHandle {
    addr: SocketAddr,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound listen address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The live serving-pressure counters.
    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Stop the loop and join it. Idempotent; the listener socket is
    /// closed (port released) before this returns.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }

    /// Block until the serving loop exits (i.e. until some other
    /// holder shuts it down or the process ends) — the foreground
    /// `serve()` entry points are built on this.
    pub fn wait(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve `listener` with `service` on a dedicated reactor thread.
/// `stats` is caller-supplied so the service can also read it when
/// composing its `\x01stats` reply.
pub fn serve_lines(
    listener: TcpListener,
    service: Arc<dyn LineService>,
    config: ServerConfig,
    stats: Arc<ServerStats>,
) -> io::Result<ServerHandle> {
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let poller = Poller::new()?;
    let waker = Arc::new(Waker::new()?);
    poller.register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)?;
    poller.register(waker.raw_fd(), TOKEN_WAKER, Interest::READ)?;

    let stop = Arc::new(AtomicBool::new(false));
    let completions = Arc::new(CompletionQueue {
        items: Mutex::new(Vec::new()),
        waker: Arc::clone(&waker),
    });
    let mut event_loop = EventLoop {
        poller,
        listener,
        listener_parked: false,
        waker: Arc::clone(&waker),
        timers: Timers::new(),
        conns: HashMap::new(),
        next_id: FIRST_CONN,
        service,
        completions,
        config,
        stats: Arc::clone(&stats),
        stop: Arc::clone(&stop),
    };
    let thread = std::thread::Builder::new()
        .name(format!("reactor-serve-{}", addr.port()))
        .spawn(move || event_loop.run())?;
    Ok(ServerHandle { addr, stats, stop, waker, thread: Some(thread) })
}

/// One admitted connection's protocol state.
#[derive(Debug)]
struct Conn {
    stream: TcpStream,
    /// Inbound bytes not yet consumed as lines.
    buf: Vec<u8>,
    /// Outbound bytes queued for the socket.
    out: Vec<u8>,
    /// How much of `out` is already written.
    written: usize,
    /// A request line is dispatched and not yet completed.
    awaiting: bool,
    /// Peer closed its write side; serve buffered lines, then close.
    eof: bool,
    /// When the last *complete* line arrived — the idle clock.
    last_line_at: Instant,
    /// When a complete buffered line started waiting behind the
    /// in-flight request (None while nothing waits) — measures the
    /// `queued` duration handed to [`LineService::serve_line`].
    queued_since: Option<Instant>,
    /// Interest currently registered with the poller.
    interest: Interest,
}

struct EventLoop {
    poller: Poller,
    listener: TcpListener,
    /// Accept hit a persistent error; listener is deregistered until
    /// the `ACCEPT_BACKOFF` timer re-arms it.
    listener_parked: bool,
    waker: Arc<Waker>,
    timers: Timers,
    conns: HashMap<u64, Conn>,
    next_id: u64,
    service: Arc<dyn LineService>,
    completions: Arc<CompletionQueue>,
    config: ServerConfig,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
}

impl EventLoop {
    fn run(&mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            let timeout = self
                .timers
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()));
            match self.poller.wait(&mut events, timeout) {
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                // a broken poller is unrecoverable; exit the loop so
                // the handle's join returns instead of spinning
                Err(_) => break,
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            for &ev in events.iter() {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.waker.drain(),
                    id => self.conn_ready(id, ev),
                }
            }
            self.drain_completions();
            self.fire_timers();
            self.drain_completions();
        }
        // teardown: closing fds deregisters them; dropping the
        // listener releases the port before the join returns
        self.conns.clear();
        self.stats.open.store(0, Ordering::Relaxed);
    }

    // ---- accept path ------------------------------------------------

    fn accept_ready(&mut self) {
        if self.listener_parked {
            return;
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => self.admit(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // fd exhaustion and friends: park the listener and
                    // retry on a timer instead of spinning hot
                    self.park_listener();
                    break;
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        let at_cap = self.config.max_connections > 0
            && self.conns.len() >= self.config.max_connections;
        if at_cap {
            self.stats.overloaded.fetch_add(1, Ordering::Relaxed);
            // best-effort refusal line; a full socket buffer means the
            // peer was not reading anyway
            let _ = stream.set_nonblocking(true);
            let _ = (&stream).write_all(OVERLOADED_LINE);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let id = self.next_id;
        self.next_id += 1;
        if self
            .poller
            .register(stream.as_raw_fd(), id, Interest::READ)
            .is_err()
        {
            return;
        }
        let now = Instant::now();
        if !self.config.idle_timeout.is_zero() {
            self.timers.arm(now + self.config.idle_timeout, id);
        }
        self.conns.insert(
            id,
            Conn {
                stream,
                buf: Vec::new(),
                out: Vec::new(),
                written: 0,
                awaiting: false,
                eof: false,
                last_line_at: now,
                queued_since: None,
                interest: Interest::READ,
            },
        );
        self.stats.open.store(self.conns.len() as u64, Ordering::Relaxed);
    }

    fn park_listener(&mut self) {
        if self.listener_parked {
            return;
        }
        let _ = self.poller.deregister(self.listener.as_raw_fd());
        self.listener_parked = true;
        self.timers.arm(Instant::now() + ACCEPT_BACKOFF, TOKEN_LISTENER);
    }

    fn unpark_listener(&mut self) {
        if !self.listener_parked {
            return;
        }
        if self
            .poller
            .register(self.listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
            .is_ok()
        {
            self.listener_parked = false;
            // catch up on anything that queued while parked
            self.accept_ready();
        } else {
            self.timers.arm(Instant::now() + ACCEPT_BACKOFF, TOKEN_LISTENER);
        }
    }

    // ---- connection IO ----------------------------------------------

    fn conn_ready(&mut self, id: u64, ev: Event) {
        if !self.conns.contains_key(&id) {
            return;
        }
        if ev.readable || ev.broken {
            if !self.conn_readable(id) {
                return;
            }
        }
        if ev.writable && !self.flush_out(id) {
            return;
        }
        self.after_io(id);
    }

    /// Drain the socket's readable bytes and dispatch complete lines.
    /// Returns false when the connection was closed.
    fn conn_readable(&mut self, id: u64) -> bool {
        let mut tmp = [0u8; 8192];
        loop {
            let conn = match self.conns.get_mut(&id) {
                Some(c) => c,
                None => return false,
            };
            if conn.eof {
                break;
            }
            match conn.stream.read(&mut tmp) {
                Ok(0) => {
                    // half-close: keep complete buffered lines, drop
                    // the partial tail (it can never complete)
                    conn.eof = true;
                    let keep = conn
                        .buf
                        .iter()
                        .rposition(|&b| b == b'\n')
                        .map(|p| p + 1)
                        .unwrap_or(0);
                    conn.buf.truncate(keep);
                    break;
                }
                Ok(n) => {
                    conn.buf.extend_from_slice(&tmp[..n]);
                    let tail = conn
                        .buf
                        .iter()
                        .rposition(|&b| b == b'\n')
                        .map(|p| conn.buf.len() - (p + 1))
                        .unwrap_or(conn.buf.len());
                    if tail > self.config.max_line_bytes {
                        // unframed flood: answer once, then hang up
                        conn.out.extend_from_slice(
                            b"{\"ok\":false,\"error\":\
                              \"request line too long\"}\n",
                        );
                        let keep = conn.buf.len() - tail;
                        conn.buf.truncate(keep);
                        conn.eof = true;
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(id);
                    return false;
                }
            }
        }
        self.advance(id)
    }

    /// Dispatch buffered complete lines, one in flight at a time.
    /// Returns false when the connection was closed.
    fn advance(&mut self, id: u64) -> bool {
        loop {
            let conn = match self.conns.get_mut(&id) {
                Some(c) => c,
                None => return false,
            };
            if conn.awaiting {
                // start the queue-wait clock the moment a complete
                // line is observed waiting behind the in-flight one
                if conn.queued_since.is_none() && conn.buf.contains(&b'\n') {
                    conn.queued_since = Some(Instant::now());
                }
                return true;
            }
            let pos = match conn.buf.iter().position(|&b| b == b'\n') {
                Some(p) => p,
                None => {
                    conn.queued_since = None;
                    return true;
                }
            };
            let line_bytes: Vec<u8> = conn.buf.drain(..=pos).collect();
            conn.last_line_at = Instant::now();
            let line = match std::str::from_utf8(&line_bytes) {
                Ok(s) => s.trim().to_string(),
                Err(_) => {
                    // not our protocol: refuse loudly and hang up
                    conn.out.extend_from_slice(
                        b"{\"ok\":false,\"error\":\
                          \"request line is not utf-8\"}\n",
                    );
                    conn.buf.clear();
                    conn.eof = true;
                    return true;
                }
            };
            if line.is_empty() {
                continue;
            }
            conn.awaiting = true;
            let queued = conn
                .queued_since
                .take()
                .map(|since| since.elapsed())
                .unwrap_or(Duration::ZERO);
            self.stats.queue_depth.fetch_add(1, Ordering::Relaxed);
            let done = Completion {
                inner: Some((id, Arc::clone(&self.completions))),
            };
            // may complete synchronously; the outcome lands in the
            // completion queue either way and is applied by
            // drain_completions, never recursively here
            self.service.serve_line(&line, queued, done);
        }
    }

    /// Apply completed requests. Loops because applying a reply can
    /// dispatch the next pipelined line, which can complete
    /// synchronously and enqueue again.
    fn drain_completions(&mut self) {
        loop {
            let batch = self.completions.drain();
            if batch.is_empty() {
                return;
            }
            for (id, outcome) in batch {
                self.stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                match outcome {
                    Outcome::Close => self.close_conn(id),
                    Outcome::Reply(line) => {
                        let conn = match self.conns.get_mut(&id) {
                            Some(c) => c,
                            // completed after the conn died (write
                            // error, shutdown): nothing to deliver to
                            None => continue,
                        };
                        conn.awaiting = false;
                        conn.out.extend_from_slice(line.as_bytes());
                        if !line.ends_with('\n') {
                            conn.out.push(b'\n');
                        }
                        if self.flush_out(id) && self.advance(id) {
                            self.after_io(id);
                        }
                    }
                }
            }
        }
    }

    /// Write queued output until done or `WouldBlock`. Returns false
    /// when the connection was closed.
    fn flush_out(&mut self, id: u64) -> bool {
        loop {
            let conn = match self.conns.get_mut(&id) {
                Some(c) => c,
                None => return false,
            };
            if conn.written >= conn.out.len() {
                conn.out.clear();
                conn.written = 0;
                return true;
            }
            match conn.stream.write(&conn.out[conn.written..]) {
                Ok(0) => {
                    self.close_conn(id);
                    return false;
                }
                Ok(n) => conn.written += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    return true;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(id);
                    return false;
                }
            }
        }
    }

    /// Post-IO disposition: close a finished connection, otherwise
    /// make the registered interest match the buffers.
    fn after_io(&mut self, id: u64) {
        let conn = match self.conns.get(&id) {
            Some(c) => c,
            None => return,
        };
        let pending_line = conn.buf.contains(&b'\n');
        let pending_out = conn.written < conn.out.len();
        if conn.eof && !conn.awaiting && !pending_out && !pending_line {
            self.close_conn(id);
            return;
        }
        let want = Interest {
            readable: !conn.eof,
            writable: pending_out,
            edge: false,
        };
        if want != conn.interest {
            let fd = conn.stream.as_raw_fd();
            if self.poller.reregister(fd, id, want).is_ok() {
                if let Some(c) = self.conns.get_mut(&id) {
                    c.interest = want;
                }
            } else {
                self.close_conn(id);
            }
        }
    }

    fn close_conn(&mut self, id: u64) {
        if let Some(conn) = self.conns.remove(&id) {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.stats
                .open
                .store(self.conns.len() as u64, Ordering::Relaxed);
        }
    }

    // ---- timers -----------------------------------------------------

    fn fire_timers(&mut self) {
        let now = Instant::now();
        let mut fired = Vec::new();
        if self.timers.pop_expired(now, &mut fired) == 0 {
            return;
        }
        for token in fired {
            if token == TOKEN_LISTENER {
                self.unpark_listener();
                continue;
            }
            let idle = self.config.idle_timeout;
            if idle.is_zero() {
                continue;
            }
            let conn = match self.conns.get(&token) {
                Some(c) => c,
                None => continue, // stale deadline (lazy cancellation)
            };
            if conn.awaiting {
                // in-flight requests are load, not idleness
                self.timers.arm(now + idle, token);
                continue;
            }
            let deadline = conn.last_line_at + idle;
            if now >= deadline {
                self.stats.idle_reaped.fetch_add(1, Ordering::Relaxed);
                self.close_conn(token);
            } else {
                // traffic pushed the idle clock back; re-arm exactly
                self.timers.arm(deadline, token);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::net::Shutdown;

    /// Echoes `line` back wrapped in brackets; `close!` drops the
    /// connection; `drop!` leaks the completion (tests the Drop
    /// error); `slow!` answers from a detached thread.
    struct Echo;
    impl LineService for Echo {
        fn serve_line(&self, line: &str, _queued: Duration, done: Completion) {
            match line {
                "close!" => done.close(),
                "drop!" => drop(done),
                "slow!" => {
                    std::thread::spawn(move || {
                        std::thread::sleep(Duration::from_millis(30));
                        done.reply("[slow!]".to_string());
                    });
                }
                _ => done.reply(format!("[{line}]")),
            }
        }
    }

    fn start(config: ServerConfig) -> ServerHandle {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        serve_lines(
            listener,
            Arc::new(Echo),
            config,
            Arc::new(ServerStats::default()),
        )
        .unwrap()
    }

    fn roundtrip(addr: SocketAddr, lines: &[&str]) -> Vec<String> {
        let mut sock = TcpStream::connect(addr).unwrap();
        for l in lines {
            sock.write_all(l.as_bytes()).unwrap();
            sock.write_all(b"\n").unwrap();
        }
        sock.shutdown(Shutdown::Write).unwrap();
        BufReader::new(sock).lines().map(|l| l.unwrap()).collect()
    }

    #[test]
    fn serves_lines_and_preserves_pipeline_order() {
        let handle = start(ServerConfig::default());
        let replies = roundtrip(handle.addr(), &["a", "b", "slow!", "c"]);
        assert_eq!(replies, vec!["[a]", "[b]", "[slow!]", "[c]"]);
    }

    #[test]
    fn half_close_still_gets_replies_and_partial_tail_is_dropped() {
        let handle = start(ServerConfig::default());
        let mut sock = TcpStream::connect(handle.addr()).unwrap();
        // one complete line + one partial line, then write-side close
        sock.write_all(b"whole\npart-with-no-newline").unwrap();
        sock.shutdown(Shutdown::Write).unwrap();
        let replies: Vec<String> =
            BufReader::new(sock).lines().map(|l| l.unwrap()).collect();
        assert_eq!(replies, vec!["[whole]"]);
    }

    #[test]
    fn dropped_completion_becomes_an_error_reply() {
        let handle = start(ServerConfig::default());
        let replies = roundtrip(handle.addr(), &["drop!", "after"]);
        assert_eq!(replies.len(), 2);
        assert!(replies[0].contains("request dropped"), "{}", replies[0]);
        assert_eq!(replies[1], "[after]");
    }

    #[test]
    fn close_outcome_discards_pipelined_lines() {
        let handle = start(ServerConfig::default());
        let replies = roundtrip(handle.addr(), &["x", "close!", "never"]);
        assert_eq!(replies, vec!["[x]"]);
    }

    #[test]
    fn overload_refusal_past_max_connections() {
        let handle = start(ServerConfig {
            max_connections: 2,
            ..ServerConfig::default()
        });
        let keep1 = TcpStream::connect(handle.addr()).unwrap();
        let keep2 = TcpStream::connect(handle.addr()).unwrap();
        // make sure both are admitted before the third knocks
        crate::util::wait::require("two admitted", Duration::from_secs(5), || {
            handle.stats().open_connections() == 2
        });
        let third = TcpStream::connect(handle.addr()).unwrap();
        let mut line = String::new();
        BufReader::new(third).read_line(&mut line).unwrap();
        assert!(line.contains("overloaded"), "{line}");
        assert_eq!(handle.stats().overloaded_rejects(), 1);
        drop((keep1, keep2));
    }

    #[test]
    fn slowloris_is_reaped_while_honest_client_is_unaffected() {
        let handle = start(ServerConfig {
            idle_timeout: Duration::from_millis(80),
            ..ServerConfig::default()
        });
        let mut dribbler = TcpStream::connect(handle.addr()).unwrap();
        let honest = std::thread::spawn({
            let addr = handle.addr();
            move || {
                // keeps completing lines the whole time the dribbler
                // is being starved out
                let mut sock = TcpStream::connect(addr).unwrap();
                let mut reader =
                    BufReader::new(sock.try_clone().unwrap());
                for _ in 0..10 {
                    sock.write_all(b"hi\n").unwrap();
                    let mut reply = String::new();
                    reader.read_line(&mut reply).unwrap();
                    assert_eq!(reply.trim_end(), "[hi]");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        });
        // dribble single bytes, never a newline: the idle clock never
        // advances, so the reaper closes us
        let mut reaped = false;
        for _ in 0..60 {
            if dribbler.write_all(b"x").is_err() {
                reaped = true;
                break;
            }
            let mut byte = [0u8; 1];
            dribbler
                .set_read_timeout(Some(Duration::from_millis(25)))
                .unwrap();
            if let Ok(0) = dribbler.read(&mut byte) {
                reaped = true;
                break;
            }
        }
        assert!(reaped, "slowloris connection was never reaped");
        assert!(handle.stats().idle_deadlines_expired() >= 1);
        honest.join().unwrap();
    }

    #[test]
    fn shutdown_releases_the_port() {
        let mut handle = start(ServerConfig::default());
        let addr = handle.addr();
        handle.shutdown();
        TcpListener::bind(addr).expect("port must be free after shutdown");
    }
}
