//! Reproduces **Figure 5**: CF T-RAG search time per query round for
//! several (trees, entities) settings — the temperature-sorting ablation
//! (§4.5.2). Round 1 is cold; later rounds benefit from bucket sorting.
//!
//! Run: `cargo bench --bench fig5`. Writes `results/fig5.csv`.

use cft_rag::bench::experiments::{fig5, ExperimentConfig};
use cft_rag::util::cli::{spec, Args};

fn main() {
    let args = Args::from_env(vec![
        spec("rounds", "query rounds", Some("10"), false),
        spec("queries", "queries per round", Some("100"), false),
        spec("repeats", "timed repeats per round", Some("10"), false),
        spec("out", "CSV output path", Some("results/fig5.csv"), false),
        spec("bench", "ignored (cargo bench passes it)", None, true),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }
    let cfg = ExperimentConfig {
        queries: args.num_or("queries", 100),
        repeats: args.num_or("repeats", 10),
        ..ExperimentConfig::default()
    };
    let settings = [(300usize, 5usize), (300, 10), (600, 5), (600, 10)];
    let csv = fig5(cfg, &settings, args.num_or("rounds", 10));
    let out = args.str_or("out", "results/fig5.csv");
    csv.write_to(&out).expect("write csv");
    println!("\nwrote {out}");
}
