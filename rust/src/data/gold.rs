//! Gold facts for accuracy judging — the langsmith/doubao replacement's
//! ground truth (DESIGN.md §Substitutions).
//!
//! For a query entity, the gold set is its full ancestor chain at its
//! first forest occurrence. Facts within `context_levels` of the entity
//! are *answerable* (a correct retriever + generator will state them);
//! deeper facts are *unanswerable* given the n-level context window —
//! they model the knowledge the paper's LLM also failed to produce,
//! which is what pins accuracy near the paper's ~66% plateau for every
//! algorithm. Any filter-induced retrieval loss lowers recall below the
//! plateau, so the judge remains sensitive to real degradations.

use crate::forest::traverse::ancestors;
use crate::forest::Forest;

/// One gold (entity, ancestor) fact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GoldFact {
    pub entity: String,
    pub related: String,
    /// Hierarchy distance (1 = parent).
    pub distance: u8,
}

/// Gold facts for one entity: the ancestor chain at its first occurrence.
pub fn gold_for_entity(forest: &Forest, entity: &str) -> Vec<GoldFact> {
    let Some(id) = forest.entity_id(entity) else {
        return Vec::new();
    };
    let addrs = forest.scan_addresses(id);
    let Some(&first) = addrs.first() else {
        return Vec::new();
    };
    ancestors(forest, first, usize::MAX)
        .into_iter()
        .enumerate()
        .map(|(i, anc)| GoldFact {
            entity: entity.to_string(),
            related: forest.entity_name(anc).to_string(),
            distance: i as u8 + 1,
        })
        .collect()
}

/// Fraction of gold facts answerable within `n` context levels — the
/// theoretical accuracy ceiling of the workload (should sit near the
/// paper's ~0.66 plateau for the default generators).
pub fn answerable_fraction(gold: &[GoldFact], n: usize) -> f64 {
    if gold.is_empty() {
        return 0.0;
    }
    let ok = gold.iter().filter(|g| (g.distance as usize) <= n).count();
    ok as f64 / gold.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Forest {
        let mut f = Forest::new();
        let ids: Vec<_> = ["a", "b", "c", "d", "e"].iter().map(|n| f.intern(n)).collect();
        let mut t = Tree::with_root(ids[0]);
        let b = t.add_child(0, ids[1]);
        let c = t.add_child(b, ids[2]);
        let d = t.add_child(c, ids[3]);
        t.add_child(d, ids[4]);
        f.add_tree(t);
        f
    }

    #[test]
    fn full_chain_with_distances() {
        let f = forest();
        let g = gold_for_entity(&f, "e");
        let rel: Vec<(&str, u8)> =
            g.iter().map(|x| (x.related.as_str(), x.distance)).collect();
        assert_eq!(rel, vec![("d", 1), ("c", 2), ("b", 3), ("a", 4)]);
    }

    #[test]
    fn answerable_fraction_counts() {
        let f = forest();
        let g = gold_for_entity(&f, "e");
        assert!((answerable_fraction(&g, 3) - 0.75).abs() < 1e-9);
        assert!((answerable_fraction(&g, 4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn root_has_no_gold() {
        let f = forest();
        assert!(gold_for_entity(&f, "a").is_empty());
        assert!(gold_for_entity(&f, "zz").is_empty());
    }
}
