//! Query workload generation (paper §4.5: queries with 5/10/20 entities,
//! repeated rounds, and the locality that temperature sorting exploits).

use crate::data::gold::{gold_for_entity, GoldFact};
use crate::data::vocab::QUERY_TEMPLATES;
use crate::forest::Forest;
use crate::util::rng::{Rng, Zipf};

/// Workload parameters.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadConfig {
    /// Entities per query (Table 2 sweeps 5/10/20).
    pub entities_per_query: usize,
    /// Queries per round.
    pub queries: usize,
    /// Zipf exponent over the entity popularity ranking (0 = uniform;
    /// paper's locality assumption needs s > 0).
    pub zipf_s: f64,
    /// Probability of drawing a *deep* entity (first occurrence at depth
    /// > context level 3): its gold ancestor chain exceeds the n-level
    /// context window, so part of it is unanswerable — this knob pins
    /// workload accuracy near the paper's ~66% plateau (see DESIGN.md
    /// §Substitutions). 0 = pure-Zipf shallow workload (accuracy ≈ 1).
    pub deep_bias: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            entities_per_query: 5,
            queries: 100,
            zipf_s: 1.1,
            deep_bias: 0.95,
            seed: 0x9E4B,
        }
    }
}

/// One generated query.
#[derive(Clone, Debug)]
pub struct Query {
    /// Natural-language surface form (entities embedded verbatim).
    pub text: String,
    /// The entity mentions (ground truth for the NER stage).
    pub entities: Vec<String>,
    /// Gold facts for the judge.
    pub gold: Vec<GoldFact>,
}

/// A deterministic query workload over a forest.
#[derive(Clone, Debug)]
pub struct Workload {
    pub queries: Vec<Query>,
}

impl Workload {
    /// Generate `cfg.queries` queries. Entities are drawn Zipf-skewed
    /// from the forest's entities ranked by occurrence count (most
    /// widespread entity = rank 0), mirroring real query locality.
    pub fn generate(forest: &Forest, cfg: WorkloadConfig) -> Workload {
        let mut rng = Rng::new(cfg.seed);

        // rank entities by occurrence count (desc), name as tiebreak for
        // determinism
        let table = forest.address_table();
        let mut ranked: Vec<(String, usize)> = table
            .iter()
            .map(|(id, addrs)| (forest.entity_name(*id).to_string(), addrs.len()))
            .collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let pool: Vec<String> = ranked.into_iter().map(|(n, _)| n).collect();
        assert!(!pool.is_empty(), "workload over empty forest");

        // deep pool: entities whose first occurrence sits well below the
        // n=3 context window — their gold chains are partially
        // unanswerable, producing the accuracy plateau. Prefer depth > 4
        // (chains ≥ 5, ≤ 60% answerable); fall back to depth > 3 on
        // shallow forests. Sorted for determinism.
        let depth_of = |addrs: &Vec<crate::forest::EntityAddress>| {
            addrs
                .first()
                .map(|a| forest.tree(a.tree).node(a.node).depth)
                .unwrap_or(0)
        };
        let mut deep: Vec<String> = table
            .iter()
            .filter(|(_, addrs)| depth_of(addrs) > 4)
            .map(|(id, _)| forest.entity_name(*id).to_string())
            .collect();
        if deep.len() < 16 {
            deep = table
                .iter()
                .filter(|(_, addrs)| depth_of(addrs) > 3)
                .map(|(id, _)| forest.entity_name(*id).to_string())
                .collect();
        }
        deep.sort();

        let zipf = Zipf::new(pool.len(), cfg.zipf_s);
        let deep_zipf =
            (!deep.is_empty()).then(|| Zipf::new(deep.len(), cfg.zipf_s));
        let mut queries = Vec::with_capacity(cfg.queries);
        for qi in 0..cfg.queries {
            let mut entities = Vec::with_capacity(cfg.entities_per_query);
            let mut guard = 0;
            while entities.len() < cfg.entities_per_query && guard < 10_000 {
                guard += 1;
                let e = match (&deep_zipf, rng.chance(cfg.deep_bias)) {
                    (Some(dz), true) => deep[dz.sample(&mut rng)].clone(),
                    _ => pool[zipf.sample(&mut rng)].clone(),
                };
                if !entities.contains(&e) {
                    entities.push(e);
                }
            }
            let template = QUERY_TEMPLATES[qi % QUERY_TEMPLATES.len()];
            let text = template.replace("{e}", &entities.join(" and also "));
            let gold = entities
                .iter()
                .flat_map(|e| gold_for_entity(forest, e))
                .collect();
            queries.push(Query { text, entities, gold });
        }
        Workload { queries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::hospital::{HospitalConfig, HospitalDataset};

    fn forest() -> Forest {
        HospitalDataset::generate(HospitalConfig {
            trees: 10,
            ..HospitalConfig::default()
        })
        .build_forest()
    }

    #[test]
    fn deterministic() {
        let f = forest();
        let a = Workload::generate(&f, WorkloadConfig::default());
        let b = Workload::generate(&f, WorkloadConfig::default());
        assert_eq!(a.queries[0].entities, b.queries[0].entities);
        assert_eq!(a.queries[0].text, b.queries[0].text);
    }

    #[test]
    fn entity_counts_respected() {
        let f = forest();
        for k in [5usize, 10, 20] {
            let w = Workload::generate(
                &f,
                WorkloadConfig { entities_per_query: k, queries: 10, ..Default::default() },
            );
            assert!(w.queries.iter().all(|q| q.entities.len() == k));
        }
    }

    #[test]
    fn entities_embedded_in_text() {
        let f = forest();
        let w = Workload::generate(&f, WorkloadConfig { queries: 5, ..Default::default() });
        for q in &w.queries {
            for e in &q.entities {
                assert!(q.text.contains(e), "{e} not in '{}'", q.text);
            }
        }
    }

    #[test]
    fn zipf_locality_repeats_hot_entities() {
        let f = forest();
        let w = Workload::generate(
            &f,
            WorkloadConfig { queries: 200, zipf_s: 1.2, ..Default::default() },
        );
        use std::collections::HashMap;
        let mut counts: HashMap<&str, usize> = HashMap::new();
        for q in &w.queries {
            for e in &q.entities {
                *counts.entry(e.as_str()).or_default() += 1;
            }
        }
        let max = counts.values().max().copied().unwrap_or(0);
        assert!(max > 40, "hottest entity only {max} draws — no locality");
    }

    #[test]
    fn gold_attached() {
        let f = forest();
        let w = Workload::generate(&f, WorkloadConfig { queries: 20, ..Default::default() });
        let with_gold = w.queries.iter().filter(|q| !q.gold.is_empty()).count();
        assert!(with_gold > 15, "most queries need gold facts");
    }
}
