//! **End-to-end serving driver** (EXPERIMENTS.md §E2E): load the AOT
//! artifacts on the PJRT CPU client, start the coordinator, replay a
//! batched query workload against the hospital knowledge base, and
//! report latency/throughput/accuracy — proving all three layers
//! compose: Pallas kernels → JAX graphs → HLO artifacts → Rust runtime →
//! coordinator.
//!
//! Run: `make artifacts && cargo run --release --example serve_requests`
//! Flags: --trees N --requests N --workers N --shards N
//!        --native (skip artifacts)
//!        --router N (serve through the shard router over N in-process
//!        TCP backends; 0 = direct coordinator) --clients N
//!        --replicas R (router mode: key-partitioned backends with
//!        R-way replication; 0 = full-index backends)
//!
//! Retrieval runs on the sharded Cuckoo filter (`--shards`, default one
//! shard per core), so worker threads retrieve in parallel instead of
//! serializing on a global retriever lock — compare `--workers 1` vs
//! `--workers 8` throughput to see the scaling. With `--router N`, each
//! backend is a full coordinator behind `coordinator/tcp.rs` and the
//! router scatter-gathers by entity-key ownership (`router/`); compare
//! `--router 1` vs `--router 4` for the scale-out story.

use std::net::TcpListener;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use cft_rag::coordinator::tcp::serve_listener;
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::data::workload::{Workload, WorkloadConfig};
use cft_rag::forest::Forest;
use cft_rag::llm::judge::{judge, Judgement};
use cft_rag::rag::config::{KeyPartition, RagConfig, RouterConfig};
use cft_rag::router::Router;
use cft_rag::runtime::engine::{Engine, NativeEngine, PjrtEngine};
use cft_rag::runtime::default_dir;
use cft_rag::util::cli::{spec, Args};
use cft_rag::util::json::Json;
use cft_rag::util::stats::Summary;

fn main() {
    let args = Args::from_env(vec![
        spec("trees", "hospital tree count", Some("100"), false),
        spec("requests", "total queries to serve", Some("256"), false),
        spec("workers", "coordinator workers", Some("4"), false),
        spec("shards", "cuckoo filter shards (0 = one per core)", Some("0"), false),
        spec("pool", "PJRT runtime pool size", Some("1"), false),
        spec("native", "use the native engine instead of PJRT", None, true),
        spec("router", "route over N in-process TCP backends (0 = direct)", Some("0"), false),
        spec("clients", "concurrent router clients (router mode)", Some("8"), false),
        spec(
            "replicas",
            "key-partition the backends with R-way replication (router mode; 0 = full-index)",
            Some("0"),
            false,
        ),
        spec("trace-out", "record the workload to a JSON trace file", None, false),
        spec("trace-in", "replay a recorded JSON trace (paced by offsets)", None, false),
    ])
    .unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.wants_help() {
        println!("{}", args.usage());
        return;
    }

    // ---- dataset + forest ----
    let trees = args.num_or("trees", 100usize);
    let ds = HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    });
    let forest = Arc::new(ds.build_forest());
    let stats = forest.stats();
    println!(
        "forest: {} trees, {} nodes, {} distinct entities, depth {}",
        stats.trees, stats.nodes, stats.distinct_entities, stats.max_depth
    );

    // ---- router mode: N in-process TCP backends behind the router ----
    let n_router = args.num_or("router", 0usize);
    if n_router > 0 {
        router_mode(&args, &ds, &forest, n_router);
        return;
    }

    let engine = build_engine(&args);
    let backend = engine.backend();

    // ---- coordinator ----
    let rag_cfg = RagConfig {
        shards: args.num_or("shards", 0),
        ..RagConfig::default()
    };
    println!(
        "retriever: sharded cuckoo ({} shards)",
        rag_cfg.resolved_shards().next_power_of_two()
    );
    let coordinator = Coordinator::start(
        forest.clone(),
        corpus_from_texts(&ds.documents()),
        engine,
        rag_cfg,
        CoordinatorConfig {
            workers: args.num_or("workers", 4),
            ..Default::default()
        },
    )
    .expect("coordinator start");

    // ---- workload ----
    let n_requests = args.num_or("requests", 256usize);
    let workload = Workload::generate(
        &forest,
        WorkloadConfig {
            entities_per_query: 5,
            queries: n_requests,
            ..Default::default()
        },
    );

    // ---- optional trace record / replay ----
    use cft_rag::data::trace::QueryTrace;
    if let Some(path) = args.get("trace-out") {
        QueryTrace::from_workload(&workload, 0.0)
            .save(path)
            .expect("write trace");
        println!("recorded trace to {path}");
    }
    let trace: Option<QueryTrace> = args
        .get("trace-in")
        .map(|p| QueryTrace::load(p).expect("read trace"));

    // ---- replay: submit requests (paced if a trace provides offsets),
    //      then collect ----
    println!("\nserving {n_requests} requests on backend {backend}...");
    let t0 = Instant::now();
    let rxs: Vec<_> = match &trace {
        Some(t) => t
            .records
            .iter()
            .zip(workload.queries.iter().cycle())
            .map(|(rec, q)| {
                let due = std::time::Duration::from_micros(rec.offset_us);
                if let Some(wait) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(wait);
                }
                (coordinator.submit(&rec.query), q)
            })
            .collect(),
        None => workload
            .queries
            .iter()
            .map(|q| (coordinator.submit(&q.text), q))
            .collect(),
    };

    let mut latencies = Vec::with_capacity(n_requests);
    let mut retrievals = Vec::with_capacity(n_requests);
    let mut judgement = Judgement::default();
    let mut failures = 0usize;
    for (rx, q) in rxs {
        // a rejected submission (queue full past the bounded wait, or
        // coordinator stopped) is a per-request failure, not a reason
        // to abort the whole replay
        let rx = match rx {
            Ok(rx) => rx,
            Err(e) => {
                failures += 1;
                eprintln!("submit failed: {e}");
                continue;
            }
        };
        match rx.recv().expect("response") {
            Ok(resp) => {
                latencies.push(resp.total_time.as_secs_f64());
                retrievals.push(resp.retrieval_time.as_secs_f64());
                judgement.merge(judge(&resp.answer, &q.gold));
            }
            Err(e) => {
                failures += 1;
                eprintln!("request failed: {e}");
            }
        }
    }
    let wall = t0.elapsed();

    // ---- report ----
    let lat = Summary::of(&latencies);
    let ret = Summary::of(&retrievals);
    let snap = coordinator.metrics().snapshot();
    println!("\n== E2E serving report ({backend}) ==");
    println!("requests:        {n_requests} ({failures} failures)");
    println!("wall time:       {:.3}s", wall.as_secs_f64());
    println!(
        "throughput:      {:.1} req/s",
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "latency (ms):    mean {:.2}  p50 {:.2}  p90 {:.2}  p99 {:.2}",
        lat.mean * 1e3,
        lat.p50 * 1e3,
        lat.p90 * 1e3,
        lat.p99 * 1e3
    );
    println!(
        "retrieval (us):  mean {:.1}  p50 {:.1}  p99 {:.1}",
        ret.mean * 1e6,
        ret.p50 * 1e6,
        ret.p99 * 1e6
    );
    println!(
        "batching:        {} batches, mean fill {:.2}",
        snap.batches, snap.mean_batch_fill
    );
    println!(
        "answer accuracy: {:.2}% ({}/{} gold facts)",
        judgement.accuracy() * 100.0,
        judgement.gold_recalled,
        judgement.gold_total
    );

    coordinator.shutdown();
}

/// Build the engine once per caller: PJRT artifacts (the real path) or
/// native fallback. Pool default 1: the PJRT CPU client parallelizes
/// executions internally; extra clients oversubscribe cores (§Perf
/// iteration 3, measured slower at pool=4).
fn build_engine(args: &Args) -> Arc<dyn Engine> {
    let pool = args.num_or("pool", 1usize);
    if args.flag("native") {
        println!("engine: native-rust (requested)");
        return Arc::new(NativeEngine::new());
    }
    match PjrtEngine::with_pool(default_dir(), pool) {
        Ok(e) => {
            println!("engine: pjrt-cpu (pool of {})", e.pool_size());
            Arc::new(e)
        }
        Err(e) => {
            println!("engine: native-rust (PJRT unavailable: {e})");
            Arc::new(NativeEngine::new())
        }
    }
}

/// `--router N`: start N full coordinators behind real TCP listeners,
/// front them with the shard router, and drive the workload from
/// `--clients` concurrent client threads — the multi-backend
/// scatter-gather path end to end, in one process.
fn router_mode(args: &Args, ds: &HospitalDataset, forest: &Arc<Forest>, n: usize) {
    let n_requests = args.num_or("requests", 256usize);
    let clients = args.num_or("clients", 8usize).max(1);
    let workers = args.num_or("workers", 4usize);
    let replicas = args.num_or("replicas", 0usize).min(n);
    let rag_cfg = RagConfig {
        shards: args.num_or("shards", 0),
        ..RagConfig::default()
    };

    // Bind every listener first: a key-partitioned backend needs the
    // full fleet address list before its index is built.
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind backend"))
        .collect();
    let addrs: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();

    // each backend gets its own engine (sharing one PJRT pool across
    // backends would serialize their neural stages on its mutexes)
    let mut backends = Vec::with_capacity(n);
    for (i, listener) in listeners.into_iter().enumerate() {
        let mut cfg = rag_cfg.clone();
        if replicas > 0 {
            cfg.replication_factor = replicas;
            cfg.key_partition = Some(
                KeyPartition::new(addrs.clone(), i, replicas)
                    .expect("partition"),
            );
        }
        let coordinator = Arc::new(
            Coordinator::start(
                forest.clone(),
                corpus_from_texts(&ds.documents()),
                build_engine(args),
                cfg,
                CoordinatorConfig { workers, ..Default::default() },
            )
            .expect("backend coordinator"),
        );
        let handle = serve_listener(coordinator.clone(), listener)
            .expect("backend listener");
        backends.push((coordinator, handle));
    }
    println!(
        "router: {n} backends ({}), {clients} clients{}",
        addrs.join(", "),
        match replicas {
            0 => " [full-index]".to_string(),
            r => format!(" [partitioned, R={r}]"),
        }
    );

    let names: Vec<String> = forest
        .interner()
        .iter()
        .map(|(_, name)| name.to_string())
        .collect();
    let router = Arc::new(
        Router::connect(
            names.iter().map(String::as_str),
            &RouterConfig {
                replication_factor: replicas,
                ..RouterConfig::for_backends(addrs)
            },
        )
        .expect("router"),
    );

    let workload = Workload::generate(
        forest,
        WorkloadConfig {
            entities_per_query: 5,
            queries: n_requests,
            ..Default::default()
        },
    );

    // ---- drive: round-robin the workload across client threads ----
    println!("\nserving {n_requests} requests through the router...");
    let judgement = Mutex::new(Judgement::default());
    let t0 = Instant::now();
    let per_client: Vec<(Vec<f64>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let router = router.clone();
                let workload = &workload;
                let judgement = &judgement;
                s.spawn(move || {
                    let mut latencies = Vec::new();
                    let mut failures = 0usize;
                    for q in workload.queries.iter().skip(c).step_by(clients) {
                        let t = Instant::now();
                        let reply = router.query(&q.text);
                        latencies.push(t.elapsed().as_secs_f64());
                        if reply.get("ok") == Some(&Json::Bool(true)) {
                            let answer = reply
                                .get("answer")
                                .and_then(Json::as_str)
                                .unwrap_or("");
                            judgement
                                .lock()
                                .unwrap()
                                .merge(judge(answer, &q.gold));
                        } else {
                            failures += 1;
                        }
                    }
                    (latencies, failures)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed();

    // ---- report ----
    let latencies: Vec<f64> =
        per_client.iter().flat_map(|(l, _)| l.iter().copied()).collect();
    let failures: usize = per_client.iter().map(|(_, f)| f).sum();
    let lat = Summary::of(&latencies);
    let snap = router.snapshot();
    let judgement = judgement.into_inner().unwrap();
    println!("\n== E2E routed serving report ({n} backends) ==");
    println!("requests:        {n_requests} ({failures} failures)");
    println!("wall time:       {:.3}s", wall.as_secs_f64());
    println!(
        "throughput:      {:.1} req/s",
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "latency (ms):    mean {:.2}  p50 {:.2}  p90 {:.2}  p99 {:.2}",
        lat.mean * 1e3,
        lat.p50 * 1e3,
        lat.p90 * 1e3,
        lat.p99 * 1e3
    );
    println!(
        "router:          {} fanouts, {} failovers, {} replica hits, \
         {} degraded",
        snap.fanouts, snap.failovers, snap.replica_hits, snap.degraded
    );
    for ((coordinator, _), b) in backends.iter().zip(&snap.backends) {
        println!(
            "  backend {:<21} {} reqs, {} failures, p99 {:.2} ms, \
             index {:.1} KiB{}",
            b.addr,
            b.requests,
            b.failures,
            b.latency_p99_s * 1e3,
            coordinator.index_bytes() as f64 / 1024.0,
            if b.healthy { "" } else { "  [down]" }
        );
    }
    println!(
        "answer accuracy: {:.2}% ({}/{} gold facts)",
        judgement.accuracy() * 100.0,
        judgement.gold_recalled,
        judgement.gold_total
    );

    drop(router); // stops the prober before the backends go away
    for (coordinator, handle) in backends {
        handle.shutdown();
        coordinator.stop();
    }
}
