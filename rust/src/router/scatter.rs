//! The scatter-gather query path.
//!
//! `Router::query` is the distributed analogue of one coordinator
//! round trip:
//!
//! 1. **Localize** — recognize the query's entity mentions (the same
//!    gazetteer the backends use) and map each to its owning backend
//!    via the rendezvous ring.
//! 2. **Route** — a query whose entities all land on one backend (or
//!    that mentions none) goes there directly, whole. A multi-owner
//!    query *scatters*: each owning backend receives only its owned
//!    mentions, so the per-backend retrieval + generation work is the
//!    owned share, not the whole query repeated N times.
//! 3. **Gather** — sub-replies merge deterministically (owner order):
//!    entity union sorted, fact counts summed, answers concatenated in
//!    owner order, stage times `max`ed (the fan-out ran in parallel).
//!
//! Failure containment: each sub-request walks the ring's failover
//! order (healthy candidates first) for up to `max_attempts` backends;
//! socket-level errors *and* `ok:false` coordinator replies (queue
//! closed, backend stopping) both trigger the next candidate. Because
//! every backend request carries the per-backend IO timeout, one slow
//! backend can only delay its own portion; if every candidate for a
//! portion fails, the merged reply is flagged `degraded` rather than
//! failing the query — unless *no* portion succeeded, which is the only
//! path to an `ok:false` reply from the router.

use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::sync::Arc;
use std::time::Instant;

use crate::error::{CftError, Result};
use crate::filter::fingerprint::entity_key;
use crate::nlp::ner::GazetteerNer;
use crate::rag::config::RouterConfig;
use crate::router::backend::Backend;
use crate::router::health::HealthProber;
use crate::router::metrics::{RouterMetrics, RouterMetricsSnapshot};
use crate::router::ring::ShardRing;
use crate::util::json::Json;
use crate::util::log;
use crate::util::rng::fnv1a;

/// One fan-out portion: the mentions routed to one owner, and the
/// outcome (serving backend index + its reply).
type Portion = (Vec<String>, io::Result<(usize, Json)>);

/// The shard router: entity-aware scatter-gather over N coordinator
/// backends. All methods take `&self`; clients query from any number of
/// threads concurrently.
pub struct Router {
    ring: ShardRing,
    backends: Vec<Arc<Backend>>,
    ner: GazetteerNer,
    metrics: RouterMetrics,
    max_attempts: usize,
    _prober: HealthProber,
}

impl Router {
    /// Build a router over `cfg.backends`, recognizing the entity
    /// vocabulary in `entity_names` (normally the forest's interner —
    /// the same names the backends index, so a mention localizes to the
    /// same key on both sides of the wire).
    pub fn connect<'a>(
        entity_names: impl IntoIterator<Item = &'a str>,
        cfg: &RouterConfig,
    ) -> Result<Router> {
        if cfg.backends.is_empty() {
            return Err(CftError::Config(
                "router needs at least one backend address".into(),
            ));
        }
        let ring = ShardRing::new(cfg.backends.iter().cloned());
        let backends: Vec<Arc<Backend>> = cfg
            .backends
            .iter()
            .enumerate()
            .map(|(i, addr)| Arc::new(Backend::new(i, addr, cfg)))
            .collect();
        let prober =
            HealthProber::start(backends.clone(), cfg.probe_interval);
        Ok(Router {
            ring,
            metrics: RouterMetrics::new(backends.len()),
            ner: GazetteerNer::new(entity_names),
            backends,
            max_attempts: cfg.max_attempts.max(1),
            _prober: prober,
        })
    }

    /// Number of fronted backends.
    pub fn num_backends(&self) -> usize {
        self.backends.len()
    }

    /// The routed backends (health inspection, tests).
    pub fn backends(&self) -> &[Arc<Backend>] {
        &self.backends
    }

    /// The ownership ring (tests, ops tooling).
    pub fn ring(&self) -> &ShardRing {
        &self.ring
    }

    /// Metrics sink handle.
    pub fn metrics(&self) -> &RouterMetrics {
        &self.metrics
    }

    /// Counters joined with live per-backend health.
    pub fn snapshot(&self) -> RouterMetricsSnapshot {
        let info: Vec<(String, bool)> = self
            .backends
            .iter()
            .map(|b| (b.addr().to_string(), b.health().is_healthy()))
            .collect();
        self.metrics.snapshot(&info)
    }

    /// Serve one query through the ring; always returns a reply object
    /// (`ok:false` only when every candidate backend for every portion
    /// failed).
    pub fn query(&self, query: &str) -> Json {
        let query = query.trim();
        let entities = self.ner.recognize(query);

        // group mentions by owning backend (healthy owners preferred;
        // BTreeMap fixes the merge order deterministically)
        let mut groups: BTreeMap<usize, Vec<String>> = BTreeMap::new();
        for e in entities {
            let owner = self.owner_of(entity_key(&e));
            groups.entry(owner).or_default().push(e);
        }

        let reply = if groups.len() <= 1 {
            // single-owner fast path: the whole query travels as-is
            let key = match groups.values().next() {
                Some(ents) => entity_key(&ents[0]),
                // no recognized entities: spread by query text so
                // entity-free traffic still load-balances
                None => fnv1a(query.as_bytes()),
            };
            match self.send_with_failover(key, query) {
                Ok((_, json)) => annotate(json, 1, false),
                Err(e) => error_reply(&e),
            }
        } else {
            self.metrics.record_fanout();
            self.scatter(query, &groups)
        };
        self.metrics
            .record_query(reply.get("ok") == Some(&Json::Bool(true)));
        reply
    }

    /// Owner of `key`: highest-ranked healthy backend, or the overall
    /// owner when nothing is currently healthy (the failover walk will
    /// try everything anyway).
    fn owner_of(&self, key: u64) -> usize {
        self.ring
            .owner_where(key, |i| self.backends[i].health().is_healthy())
            .or_else(|| self.ring.owner(key))
            .expect("ring is non-empty by construction")
    }

    /// Fan the owned mention groups out in parallel and merge.
    fn scatter(&self, query: &str, groups: &BTreeMap<usize, Vec<String>>) -> Json {
        let parts: Vec<Portion> = std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .values()
                .map(|ents| {
                    s.spawn(move || {
                        // The sub-request carries only this owner's
                        // mentions; its first mention keys the failover
                        // walk. Joined with " and ": the backend
                        // normalizes punctuation away, so the separator
                        // must be a word no entity name contains, or
                        // adjacent mentions could bridge into a
                        // spurious longer match.
                        let line = ents.join(" and ");
                        let key = entity_key(&ents[0]);
                        (ents.clone(), self.send_with_failover(key, &line))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("scatter worker panicked"))
                .collect()
        });
        self.merge(query, parts)
    }

    /// Try `line` against the ring's candidates for `key`: healthy
    /// backends in rank order first, then (still within `max_attempts`)
    /// the unhealthy ones — a marked-down backend may have just come
    /// back, and trying it last costs nothing when everything else is
    /// gone. An `ok:false` protocol reply is treated like a transport
    /// failure for candidate-walking purposes, but does *not* demote
    /// the backend's health (it answered; the coordinator refused).
    fn send_with_failover(
        &self,
        key: u64,
        line: &str,
    ) -> io::Result<(usize, Json)> {
        let ranked = self.ring.ranked(key);
        // one health read per candidate: reading twice (a healthy pass
        // then an unhealthy pass) would let a concurrent health flip
        // duplicate a candidate and crowd a live one out of the
        // max_attempts window
        let (mut order, unhealthy): (Vec<usize>, Vec<usize>) = ranked
            .iter()
            .copied()
            .partition(|&i| self.backends[i].health().is_healthy());
        order.extend(unhealthy);
        order.truncate(self.max_attempts);
        let owner = ranked[0];
        let mut last_err = io::Error::new(
            io::ErrorKind::NotConnected,
            "no backend candidates",
        );
        for idx in order {
            let t0 = Instant::now();
            match self.backends[idx].request(line) {
                Ok(json) => {
                    let ok = json.get("ok") != Some(&Json::Bool(false));
                    self.metrics.record_backend(idx, ok, t0.elapsed());
                    if !ok {
                        let msg = json
                            .get("error")
                            .and_then(Json::as_str)
                            .unwrap_or("backend refused")
                            .to_string();
                        last_err = io::Error::other(msg);
                        continue;
                    }
                    if idx != owner {
                        self.metrics.record_failover();
                    }
                    return Ok((idx, json));
                }
                Err(e) => {
                    self.metrics.record_backend(idx, false, t0.elapsed());
                    last_err = e;
                }
            }
        }
        Err(last_err)
    }

    /// Deterministic merge of the fan-out's portions (already in owner
    /// order — `scatter` walks a `BTreeMap`).
    fn merge(
        &self,
        query: &str,
        parts: Vec<Portion>,
    ) -> Json {
        let mut entities: BTreeSet<String> = BTreeSet::new();
        let mut answers: Vec<String> = Vec::new();
        let mut facts = 0.0;
        let mut retrieval_us: f64 = 0.0;
        let mut total_ms: f64 = 0.0;
        let mut served = 0usize;
        let mut missing: Vec<String> = Vec::new();
        let mut last_err = String::new();

        for (ents, outcome) in parts {
            match outcome {
                Ok((_, json)) => {
                    served += 1;
                    if let Some(arr) =
                        json.get("entities").and_then(Json::as_arr)
                    {
                        entities.extend(
                            arr.iter()
                                .filter_map(Json::as_str)
                                .map(str::to_string),
                        );
                    }
                    if let Some(a) = json.get("answer").and_then(Json::as_str)
                    {
                        if !a.is_empty() {
                            answers.push(a.to_string());
                        }
                    }
                    facts +=
                        json.get("facts").and_then(Json::as_f64).unwrap_or(0.0);
                    retrieval_us = retrieval_us.max(
                        json.get("retrieval_us")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    );
                    total_ms = total_ms.max(
                        json.get("total_ms")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    );
                }
                Err(e) => {
                    missing.extend(ents);
                    last_err = e.to_string();
                }
            }
        }

        if served == 0 {
            log::error!("query {query:?}: every portion failed ({last_err})");
            return error_reply(&io::Error::other(last_err));
        }
        let degraded = !missing.is_empty();
        if degraded {
            self.metrics.record_degraded();
            log::warn!(
                "degraded reply for {query:?}: no backend served {missing:?} \
                 ({last_err})"
            );
        }
        let mut reply = annotate(
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("answer", Json::Str(answers.join("\n"))),
                (
                    "entities",
                    Json::Arr(
                        entities.into_iter().map(Json::Str).collect(),
                    ),
                ),
                ("facts", Json::Num(facts)),
                ("retrieval_us", Json::Num(retrieval_us)),
                ("total_ms", Json::Num(total_ms)),
            ]),
            served,
            degraded,
        );
        if degraded {
            if let Json::Obj(m) = &mut reply {
                m.insert(
                    "missing_entities".into(),
                    Json::Arr(missing.into_iter().map(Json::Str).collect()),
                );
            }
        }
        reply
    }
}

/// Stamp the router fields onto a backend (or merged) reply.
fn annotate(reply: Json, backends: usize, degraded: bool) -> Json {
    match reply {
        Json::Obj(mut m) => {
            m.insert("backends".into(), Json::Num(backends as f64));
            m.insert("degraded".into(), Json::Bool(degraded));
            Json::Obj(m)
        }
        other => other,
    }
}

/// The router's terminal failure reply.
fn error_reply(e: &io::Error) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(format!("all backends failed: {e}"))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_requires_backends() {
        let err = Router::connect(["cardiology"], &RouterConfig::default())
            .expect_err("no backends configured");
        assert!(err.to_string().contains("backend"), "{err}");
    }

    #[test]
    fn annotate_and_error_shapes() {
        let r = annotate(
            Json::obj(vec![("ok", Json::Bool(true))]),
            3,
            true,
        );
        assert_eq!(r.get("backends").and_then(Json::as_f64), Some(3.0));
        assert_eq!(r.get("degraded"), Some(&Json::Bool(true)));
        let e = error_reply(&io::Error::other("boom"));
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert!(e
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("boom"));
    }
}
