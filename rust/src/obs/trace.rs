//! Distributed request tracing for the serving stack.
//!
//! A trace id is minted at whichever front door a request enters (the
//! shard router or a coordinator) when head sampling selects it, and
//! rides to backends as an optional `\x01t=<hex> ` prefix on protocol
//! lines ([`prefix_line`]/[`strip_trace`]). A peer that predates this
//! module rejects the prefixed line as an unknown control — the
//! documented behavior for every unrecognized `\x01` verb — so a fleet
//! upgrades one process at a time with tracing simply disabled across
//! mixed-version edges.
//!
//! Spans are recorded with [`record`] into fixed-size per-thread rings
//! of relaxed atomics: the owning thread writes, any thread may read,
//! and a per-slot sequence word discards the (vanishingly rare) slot
//! caught mid-write — every access is an atomic operation, so the
//! protocol is clean under ThreadSanitizer/Miri, and a torn slot costs
//! one telemetry sample, never a data race. Completed sampled requests
//! register a root record ([`finish_root`]); the `\x01trace` control
//! line exports the most recent roots with their span trees as JSON
//! ([`export_json`]), and slow queries additionally emit a structured
//! `slow_query` log line ([`log_slow`]).
//!
//! Clock note: span timestamps are offsets from a process-wide epoch
//! taken at first use, using [`crate::sync::time::Instant`] so the
//! arithmetic stays inside the model-check clock shim's rules.

use std::collections::VecDeque;
use std::sync::OnceLock;
use std::time::Duration;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::time::Instant;
use crate::sync::{Arc, Mutex};
use crate::util::json::Json;
use crate::util::log;

/// Front-door label for coordinator-rooted traces.
pub const DOOR_COORDINATOR: &str = "coordinator";
/// Front-door label for router-rooted traces.
pub const DOOR_ROUTER: &str = "router";

/// Wire prefix carrying a trace id on a protocol line.
pub const TRACE_PREFIX: &str = "\x01t=";

/// Spans retained per recording thread (newest overwrite oldest).
const RING_SPANS: usize = 256;
/// Completed sampled roots retained for `\x01trace` export.
const RECENT_ROOTS: usize = 64;

/// The named stages a request can pass through; one span per stage
/// occurrence. `docs/OBSERVABILITY.md` documents each stage's meaning
/// and what to suspect when it dominates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Root span: front-door wall time, dispatch to reply.
    Request = 0,
    /// A complete line sat buffered behind its pipelined predecessor
    /// before the reactor could dispatch it.
    ReactorQueue = 1,
    /// Router front door: wait in the worker-pool dispatch queue.
    DispatchWait = 2,
    /// Coordinator: wait in the submit queue before the batcher saw
    /// the request.
    SubmitWait = 3,
    /// Coordinator: batch formation window (batcher saw the request →
    /// batch dispatched).
    BatchWait = 4,
    /// Coordinator: embedding + document search for the request's
    /// batch chunk (includes waiting for earlier chunks of the same
    /// batch; `arg` = chunk size).
    EmbedSearch = 5,
    /// Coordinator: wait in the worker queue between batch dispatch
    /// and a worker picking the request up.
    WorkerWait = 6,
    /// Entity recognition over the query text.
    Ner = 7,
    /// Filter-backed context retrieval (`arg` = cuckoo slots probed,
    /// when the retriever exposes probe counters).
    Retrieval = 8,
    /// Prompt assembly + answer generation.
    Generate = 9,
    /// Router: one backend exchange — connect/write/reply against the
    /// outbound reactor's deadline (`arg` = backend index).
    Exchange = 10,
    /// Router: deterministic merge of scattered portions.
    Merge = 11,
}

/// Every stage, indexable by the `repr(u8)` discriminant.
pub const STAGES: [Stage; 12] = [
    Stage::Request,
    Stage::ReactorQueue,
    Stage::DispatchWait,
    Stage::SubmitWait,
    Stage::BatchWait,
    Stage::EmbedSearch,
    Stage::WorkerWait,
    Stage::Ner,
    Stage::Retrieval,
    Stage::Generate,
    Stage::Exchange,
    Stage::Merge,
];

impl Stage {
    /// Stable snake_case name used in exports, logs and docs.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Request => "request",
            Stage::ReactorQueue => "reactor_queue",
            Stage::DispatchWait => "dispatch_wait",
            Stage::SubmitWait => "submit_wait",
            Stage::BatchWait => "batch_wait",
            Stage::EmbedSearch => "embed_search",
            Stage::WorkerWait => "worker_wait",
            Stage::Ner => "ner",
            Stage::Retrieval => "retrieval",
            Stage::Generate => "generate",
            Stage::Exchange => "exchange",
            Stage::Merge => "merge",
        }
    }

    fn from_u8(v: u8) -> Option<Stage> {
        STAGES.get(v as usize).copied()
    }
}

/// A request's trace identity. The zero id means "not sampled": every
/// recording call is a no-op for it, which is what bounds disabled
/// tracing to a branch per stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceId(u64);

impl TraceId {
    /// The unsampled id.
    pub const NONE: TraceId = TraceId(0);

    /// True if spans should be recorded for this request.
    pub fn is_sampled(self) -> bool {
        self.0 != 0
    }

    /// Raw id (0 for [`TraceId::NONE`]).
    pub fn raw(self) -> u64 {
        self.0
    }

    /// Rebuild from a raw id.
    pub fn from_raw(raw: u64) -> TraceId {
        TraceId(raw)
    }

    /// Lowercase hex form (the wire and export encoding).
    pub fn to_hex(self) -> String {
        format!("{:x}", self.0)
    }

    /// Parse the hex form; `None` for malformed or zero input.
    pub fn from_hex(s: &str) -> Option<TraceId> {
        match u64::from_str_radix(s, 16) {
            Ok(0) | Err(_) => None,
            Ok(v) => Some(TraceId(v)),
        }
    }
}

/// Mint a fresh process-unique trace id (never [`TraceId::NONE`]).
pub fn mint() -> TraceId {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    // splitmix64 over a sequence counter: unique per process, and the
    // mixing spreads ids so prefixes differ visibly in logs.
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let mut z = n.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    TraceId(if z == 0 { 1 } else { z })
}

/// Head-sampling policy owned by one front door (deliberately not
/// global: a process can host several doors — tests do — each with its
/// own `RagConfig`/`RouterConfig` knobs).
#[derive(Debug)]
pub struct Sampler {
    every: u64,
    slow: Duration,
    seq: AtomicU64,
}

impl Sampler {
    /// Sample one request in `every` (0 disables sampling); requests
    /// slower than `slow` are flagged and logged regardless (0
    /// disables the slow path too).
    pub fn new(every: u64, slow: Duration) -> Sampler {
        Sampler { every, slow, seq: AtomicU64::new(0) }
    }

    /// A sampler that never samples and never flags slow queries.
    pub fn disabled() -> Sampler {
        Sampler::new(0, Duration::ZERO)
    }

    /// Head-sampling decision for the next request: a fresh id for
    /// every `every`-th arrival, [`TraceId::NONE`] otherwise.
    pub fn begin(&self) -> TraceId {
        if self.every == 0 {
            return TraceId::NONE;
        }
        let n = self.seq.fetch_add(1, Ordering::Relaxed);
        if n % self.every == 0 { mint() } else { TraceId::NONE }
    }

    /// True if a completed request's wall time crosses the slow-query
    /// threshold.
    pub fn is_slow(&self, total: Duration) -> bool {
        self.slow > Duration::ZERO && total >= self.slow
    }

    /// The configured sampling period (0 = disabled).
    pub fn sample_every(&self) -> u64 {
        self.every
    }

    /// The configured slow-query threshold (0 = disabled).
    pub fn slow_threshold(&self) -> Duration {
        self.slow
    }
}

/// One recorded span, as read back from the rings.
#[derive(Clone, Copy, Debug)]
pub struct SpanRec {
    /// Raw trace id the span belongs to.
    pub trace: u64,
    /// Which stage the span measures.
    pub stage: Stage,
    /// Stage-specific argument (backend index, chunk size, slots
    /// probed…; 0 when unused).
    pub arg: u32,
    /// Start offset from the process trace epoch, nanoseconds.
    pub start_ns: u64,
    /// Span duration, nanoseconds.
    pub dur_ns: u64,
}

/// A completed sampled request, as retained for `\x01trace`.
#[derive(Clone, Copy, Debug)]
pub struct RootRec {
    /// Raw trace id.
    pub id: u64,
    /// Which front door rooted the trace ([`DOOR_COORDINATOR`] /
    /// [`DOOR_ROUTER`]).
    pub door: &'static str,
    /// Root start offset from the process trace epoch, nanoseconds.
    pub start_ns: u64,
    /// Front-door wall time, nanoseconds.
    pub dur_ns: u64,
    /// Whether the request crossed its door's slow-query threshold.
    pub slow: bool,
}

struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    meta: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
}

/// Per-thread span ring. Single writer (the owning thread), any
/// readers; all fields are atomics, the `seq` word is odd while a
/// write is in flight and bumps on completion, so readers can detect
/// and drop a slot they raced with.
struct SpanRing {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

impl SpanRing {
    fn new() -> SpanRing {
        SpanRing {
            head: AtomicU64::new(0),
            slots: (0..RING_SPANS)
                .map(|_| Slot {
                    seq: AtomicU64::new(0),
                    trace: AtomicU64::new(0),
                    meta: AtomicU64::new(0),
                    start_ns: AtomicU64::new(0),
                    dur_ns: AtomicU64::new(0),
                })
                .collect(),
        }
    }

    fn push(&self, trace: u64, stage: Stage, arg: u32, start_ns: u64, dur_ns: u64) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(h as usize) % RING_SPANS];
        slot.seq.store(2 * h + 1, Ordering::Release);
        slot.trace.store(trace, Ordering::Relaxed);
        slot.meta.store(((stage as u64) << 32) | u64::from(arg), Ordering::Relaxed);
        slot.start_ns.store(start_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.seq.store(2 * h + 2, Ordering::Release);
        self.head.store(h.wrapping_add(1), Ordering::Release);
    }

    fn collect_into(&self, trace: u64, out: &mut Vec<SpanRec>) {
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue; // empty, or a write is in flight
            }
            if slot.trace.load(Ordering::Relaxed) != trace {
                continue;
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let start_ns = slot.start_ns.load(Ordering::Relaxed);
            let dur_ns = slot.dur_ns.load(Ordering::Relaxed);
            let s2 = slot.seq.load(Ordering::Acquire);
            if s1 != s2 {
                continue; // overwritten while reading; drop the sample
            }
            let Some(stage) = Stage::from_u8((meta >> 32) as u8) else {
                continue;
            };
            out.push(SpanRec { trace, stage, arg: meta as u32, start_ns, dur_ns });
        }
    }
}

/// Process-wide trace sink: the registered per-thread rings plus the
/// bounded list of recently completed sampled roots.
struct TraceHub {
    epoch: Instant,
    rings: Mutex<Vec<Arc<SpanRing>>>,
    recent: Mutex<VecDeque<RootRec>>,
}

fn hub() -> &'static TraceHub {
    static HUB: OnceLock<TraceHub> = OnceLock::new();
    HUB.get_or_init(|| TraceHub {
        epoch: Instant::now(),
        rings: Mutex::new(Vec::new()),
        recent: Mutex::new(VecDeque::new()),
    })
}

thread_local! {
    static RING: Arc<SpanRing> = {
        let ring = Arc::new(SpanRing::new());
        hub().rings.lock().unwrap().push(Arc::clone(&ring));
        ring
    };
}

fn to_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

fn since_epoch_ns(at: Instant) -> u64 {
    to_ns(at.duration_since(hub().epoch))
}

/// Record one span. A no-op (one branch) when `trace` is unsampled —
/// cheap enough to leave on every hot path unconditionally.
pub fn record(trace: TraceId, stage: Stage, arg: u32, start: Instant, dur: Duration) {
    if !trace.is_sampled() {
        return;
    }
    let start_ns = since_epoch_ns(start);
    RING.with(|ring| ring.push(trace.raw(), stage, arg, start_ns, to_ns(dur)));
}

/// Record the root span for a completed front-door request and retain
/// it for `\x01trace` export. No-op for unsampled ids.
pub fn finish_root(trace: TraceId, door: &'static str, start: Instant, total: Duration, slow: bool) {
    if !trace.is_sampled() {
        return;
    }
    record(trace, Stage::Request, 0, start, total);
    let rec = RootRec {
        id: trace.raw(),
        door,
        start_ns: since_epoch_ns(start),
        dur_ns: to_ns(total),
        slow,
    };
    let mut recent = hub().recent.lock().unwrap();
    recent.push_back(rec);
    while recent.len() > RECENT_ROOTS {
        recent.pop_front();
    }
}

/// Emit the structured slow-query log line (one per slow request,
/// whatever the sampling decision was; unsampled requests log
/// `trace=-` and carry no span detail).
pub fn log_slow(door: &str, trace: TraceId, total: Duration, line: &str) {
    let id = if trace.is_sampled() { trace.to_hex() } else { "-".to_string() };
    let snippet: String = line.chars().take(120).collect();
    log::warn!(
        "slow_query door={door} trace={id} total_ms={:.3} line={snippet:?}",
        total.as_secs_f64() * 1e3
    );
}

/// All spans recorded for `trace`, across every thread's ring, sorted
/// by start time.
pub fn spans_for(trace: TraceId) -> Vec<SpanRec> {
    if !trace.is_sampled() {
        return Vec::new();
    }
    let rings: Vec<Arc<SpanRing>> = hub().rings.lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in &rings {
        ring.collect_into(trace.raw(), &mut out);
    }
    out.sort_by_key(|s| (s.start_ns, s.stage as u8));
    out
}

/// The retained root record for `trace`, if it completed recently.
pub fn root_for(trace: TraceId) -> Option<RootRec> {
    hub().recent.lock().unwrap().iter().rev().find(|r| r.id == trace.raw()).copied()
}

/// Fraction of the root interval `[root_start_ns, root_start_ns +
/// root_dur_ns)` covered by the union of the given `(start_ns,
/// dur_ns)` child intervals, clipped to the root. Overlapping children
/// (parallel backend exchanges) count once; an empty root counts as
/// fully covered.
pub fn coverage(root_start_ns: u64, root_dur_ns: u64, spans: &[(u64, u64)]) -> f64 {
    if root_dur_ns == 0 {
        return 1.0;
    }
    let lo = root_start_ns;
    let hi = root_start_ns.saturating_add(root_dur_ns);
    let mut iv: Vec<(u64, u64)> = spans
        .iter()
        .map(|&(s, d)| (s.max(lo), s.saturating_add(d).min(hi)))
        .filter(|&(s, e)| e > s)
        .collect();
    iv.sort_unstable();
    let mut covered = 0u64;
    let mut cursor = lo;
    for (s, e) in iv {
        let s = s.max(cursor);
        if e > s {
            covered += e - s;
            cursor = e;
        }
    }
    covered as f64 / root_dur_ns as f64
}

fn trace_to_json(root: &RootRec, spans: &[SpanRec]) -> Json {
    let child_iv: Vec<(u64, u64)> = spans
        .iter()
        .filter(|s| s.stage != Stage::Request)
        .map(|s| (s.start_ns, s.dur_ns))
        .collect();
    let span_json = spans
        .iter()
        .map(|s| {
            let rel_us =
                (s.start_ns.saturating_sub(root.start_ns)) as f64 / 1e3;
            Json::obj(vec![
                ("stage", Json::Str(s.stage.name().to_string())),
                ("arg", Json::Num(f64::from(s.arg))),
                ("start_us", Json::Num(rel_us)),
                ("dur_us", Json::Num(s.dur_ns as f64 / 1e3)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("id", Json::Str(format!("{:x}", root.id))),
        ("door", Json::Str(root.door.to_string())),
        ("total_ms", Json::Num(root.dur_ns as f64 / 1e6)),
        ("slow", Json::Bool(root.slow)),
        (
            "coverage",
            Json::Num(coverage(root.start_ns, root.dur_ns, &child_iv)),
        ),
        ("spans", Json::Arr(span_json)),
    ])
}

/// The `\x01trace` reply payload: the retained roots (newest first,
/// up to `limit`; or just the one matching `filter`) with their span
/// trees and per-trace coverage.
pub fn export_json(filter: Option<TraceId>, limit: usize) -> Json {
    let roots: Vec<RootRec> = {
        let recent = hub().recent.lock().unwrap();
        match filter {
            Some(id) => recent.iter().rev().filter(|r| r.id == id.raw()).take(1).copied().collect(),
            None => recent.iter().rev().take(limit).copied().collect(),
        }
    };
    let traces = roots
        .iter()
        .map(|root| trace_to_json(root, &spans_for(TraceId::from_raw(root.id))))
        .collect();
    Json::obj(vec![("ok", Json::Bool(true)), ("traces", Json::Arr(traces))])
}

/// Prefix a protocol line with the trace id for propagation to a
/// backend. Unsampled ids return the line unchanged.
pub fn prefix_line(trace: TraceId, line: &str) -> String {
    if trace.is_sampled() {
        format!("{TRACE_PREFIX}{:x} {line}", trace.raw())
    } else {
        line.to_string()
    }
}

/// Split an inbound line into its (optional) trace id and the payload.
/// Lines without a well-formed `\x01t=<hex> ` prefix come back
/// untouched with [`TraceId::NONE`] — in particular a *malformed*
/// prefix is left on the line, which the control-line parser then
/// rejects as an unknown `\x01` verb, preserving the old-peer
/// behavior the incremental-upgrade story depends on.
pub fn strip_trace(line: &str) -> (TraceId, &str) {
    if let Some(rest) = line.strip_prefix(TRACE_PREFIX) {
        if let Some((id_part, payload)) = rest.split_once(' ') {
            if let Some(id) = TraceId::from_hex(id_part) {
                return (id, payload);
            }
        }
    }
    (TraceId::NONE, line)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_is_unique_and_sampled() {
        let a = mint();
        let b = mint();
        assert_ne!(a, b);
        assert!(a.is_sampled() && b.is_sampled());
        assert!(!TraceId::NONE.is_sampled());
    }

    #[test]
    fn hex_roundtrip() {
        let id = mint();
        assert_eq!(TraceId::from_hex(&id.to_hex()), Some(id));
        assert_eq!(TraceId::from_hex("zz"), None);
        assert_eq!(TraceId::from_hex("0"), None, "zero is reserved for NONE");
    }

    #[test]
    fn sampler_period_and_slow_threshold() {
        let s = Sampler::new(4, Duration::from_millis(10));
        let sampled = (0..8).filter(|_| s.begin().is_sampled()).count();
        assert_eq!(sampled, 2, "one in four over eight arrivals");
        assert!(s.is_slow(Duration::from_millis(10)));
        assert!(!s.is_slow(Duration::from_millis(9)));
        let off = Sampler::disabled();
        assert!(!off.begin().is_sampled());
        assert!(!off.is_slow(Duration::from_secs(60)));
    }

    #[test]
    fn wire_prefix_roundtrip() {
        let id = mint();
        let line = prefix_line(id, "what is cardiology");
        assert!(line.starts_with(TRACE_PREFIX));
        let (back, payload) = strip_trace(&line);
        assert_eq!(back, id);
        assert_eq!(payload, "what is cardiology");
        // unsampled: untouched
        assert_eq!(prefix_line(TraceId::NONE, "q"), "q");
        // plain lines and malformed prefixes come back as-is
        assert_eq!(strip_trace("plain query"), (TraceId::NONE, "plain query"));
        let bad = "\x01t=nothex query";
        assert_eq!(strip_trace(bad), (TraceId::NONE, bad));
        let no_payload = "\x01t=abc";
        assert_eq!(strip_trace(no_payload), (TraceId::NONE, no_payload));
    }

    #[test]
    fn spans_record_and_collect_across_threads() {
        let id = mint();
        let t0 = Instant::now();
        record(id, Stage::Ner, 0, t0, Duration::from_micros(50));
        let id2 = id;
        crate::sync::thread::spawn(move || {
            record(id2, Stage::Retrieval, 7, Instant::now(), Duration::from_micros(80));
        })
        .join()
        .unwrap();
        let spans = spans_for(id);
        assert_eq!(spans.len(), 2);
        let stages: Vec<&str> = spans.iter().map(|s| s.stage.name()).collect();
        assert!(stages.contains(&"ner") && stages.contains(&"retrieval"));
        let retr = spans.iter().find(|s| s.stage == Stage::Retrieval).unwrap();
        assert_eq!(retr.arg, 7);
        // unsampled recording is a no-op
        record(TraceId::NONE, Stage::Ner, 0, Instant::now(), Duration::ZERO);
        assert!(spans_for(TraceId::NONE).is_empty());
    }

    #[test]
    fn finish_root_retains_and_exports() {
        let id = mint();
        let t0 = Instant::now();
        record(id, Stage::Retrieval, 3, t0, Duration::from_millis(9));
        finish_root(id, DOOR_COORDINATOR, t0, Duration::from_millis(10), true);
        let root = root_for(id).expect("root retained");
        assert_eq!(root.door, DOOR_COORDINATOR);
        assert!(root.slow);
        let json = export_json(Some(id), 8);
        assert_eq!(json.get("ok"), Some(&Json::Bool(true)));
        let traces = json.get("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.get("id").and_then(Json::as_str), Some(id.to_hex().as_str()));
        assert_eq!(t.get("slow"), Some(&Json::Bool(true)));
        let cov = t.get("coverage").and_then(Json::as_f64).unwrap();
        assert!(cov > 0.85 && cov <= 1.0, "9ms of 10ms covered, got {cov}");
        let spans = t.get("spans").unwrap().as_arr().unwrap();
        assert!(spans.iter().any(|s| {
            s.get("stage").and_then(Json::as_str) == Some("request")
        }));
        for s in spans {
            assert!(s.get("dur_us").and_then(Json::as_f64).unwrap() >= 0.0);
            assert!(s.get("start_us").and_then(Json::as_f64).unwrap() >= 0.0);
        }
        // the reply parses back through the crate's own JSON parser
        assert!(Json::parse(&json.to_string()).is_ok());
    }

    #[test]
    fn export_without_filter_lists_recent_roots() {
        let id = mint();
        finish_root(id, DOOR_ROUTER, Instant::now(), Duration::from_millis(1), false);
        let json = export_json(None, RECENT_ROOTS);
        let traces = json.get("traces").unwrap().as_arr().unwrap();
        assert!(traces
            .iter()
            .any(|t| t.get("id").and_then(Json::as_str) == Some(id.to_hex().as_str())));
    }

    #[test]
    fn coverage_unions_and_clips() {
        // root [100, 200): two overlapping children + one outside
        let spans = [(100, 40), (120, 50), (500, 100)];
        let cov = coverage(100, 100, &spans);
        assert!((cov - 0.7).abs() < 1e-12, "[100,170) = 70% covered, got {cov}");
        assert_eq!(coverage(0, 0, &[]), 1.0);
        assert_eq!(coverage(0, 100, &[]), 0.0);
        assert_eq!(coverage(0, 100, &[(0, 100)]), 1.0);
        // child longer than the root is clipped
        assert_eq!(coverage(50, 100, &[(0, 1000)]), 1.0);
    }

    #[test]
    fn ring_overwrite_keeps_newest() {
        let id = mint();
        let t0 = Instant::now();
        for _ in 0..(RING_SPANS + 10) {
            record(id, Stage::Exchange, 1, t0, Duration::from_micros(1));
        }
        let spans = spans_for(id);
        assert!(!spans.is_empty());
        assert!(spans.len() <= RING_SPANS);
    }
}
