//! Model-checkable threading (`--features modelcheck`).
//!
//! `spawn` on a model vthread creates another *virtual* thread under
//! the scheduler (a real OS thread, but one that only runs when
//! scheduled); anywhere else it is `std::thread::spawn`. `sleep`
//! under a model run parks the vthread until **virtual** time reaches
//! the deadline — sleeps cost nothing in wall-clock terms and fire in
//! deterministic deadline order.

use std::any::Any;
use std::fmt;
use std::marker::PhantomData;
use std::sync::Arc;
use std::time::Duration;

use crate::modelcheck::{managed, Shared, RES_SLEEP};

enum HandleImpl<T> {
    Std(std::thread::JoinHandle<T>),
    Virt {
        shared: Arc<Shared>,
        vtid: usize,
        _result: PhantomData<fn() -> T>,
    },
}

/// Drop-in [`std::thread::JoinHandle`].
pub struct JoinHandle<T>(HandleImpl<T>);

/// See [`std::thread::spawn`]. On a model vthread the child becomes a
/// virtual thread: it starts parked and runs only when the scheduler
/// picks it, so the spawner keeps the CPU until its next sync point.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    if let Some((sh, _)) = managed() {
        let vtid = sh.spawn_vthread(
            None,
            Box::new(move || Box::new(f()) as Box<dyn Any + Send>),
        );
        JoinHandle(HandleImpl::Virt { shared: sh, vtid, _result: PhantomData })
    } else {
        JoinHandle(HandleImpl::Std(std::thread::spawn(f)))
    }
}

impl<T> JoinHandle<T> {
    /// See [`std::thread::JoinHandle::join`]. Joining a virtual thread
    /// is a scheduling point; a panic in the child surfaces here (and
    /// fails the schedule with the child's panic message).
    pub fn join(self) -> std::thread::Result<T> {
        match self.0 {
            HandleImpl::Std(h) => h.join(),
            HandleImpl::Virt { shared, vtid, .. } => {
                let (_, me) = managed().expect(
                    "modelcheck join: virtual threads can only be joined \
                     from inside their model run",
                );
                match shared.join_vthread(me, vtid) {
                    Ok(boxed) => Ok(*boxed
                        .downcast::<T>()
                        .expect("vthread result has the spawned type")),
                    Err(payload) => Err(payload),
                }
            }
        }
    }
}

impl<T> fmt::Debug for JoinHandle<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JoinHandle").finish_non_exhaustive()
    }
}

/// See [`std::thread::sleep`]. Virtual (instant, deterministic) under
/// a model run; real otherwise.
pub fn sleep(dur: Duration) {
    if let Some((sh, vtid)) = managed() {
        sh.block(vtid, RES_SLEEP, "sleep", Some(dur));
    } else {
        std::thread::sleep(dur);
    }
}

/// See [`std::thread::yield_now`]. A plain scheduling point under a
/// model run.
pub fn yield_now() {
    if let Some((sh, vtid)) = managed() {
        sh.yield_point(vtid);
    } else {
        std::thread::yield_now();
    }
}
