//! Hand-rolled, dependency-free readiness reactor — the nonblocking
//! serving core under both front doors and the router's outbound
//! wire traffic.
//!
//! The seed served every TCP connection on its own blocked OS thread,
//! which caps concurrent clients at thread-pool scale and makes
//! per-request deadlines expensive (socket timeouts are per-stream,
//! set once at connect). This module replaces that with mio-style
//! readiness polling over nonblocking sockets — no external crates,
//! `extern "C"` straight to `epoll`/`poll(2)` — so connections cost a
//! few hundred bytes of state instead of a stack, and deadlines are
//! exact timer entries instead of kernel socket options.
//!
//! Layout, bottom up:
//!
//! * [`sys`] — the one thin unsafe layer: [`sys::Poller`]
//!   (epoll on Linux, `poll(2)` elsewhere), [`sys::Waker`]
//!   (cross-thread loop wakeup), and the Linux nonblocking-connect
//!   helpers.
//! * [`timer`] — [`timer::Timers`], exact-deadline bookkeeping with
//!   lazy cancellation, used for idle reaping, accept backoff, and
//!   per-request deadlines.
//! * [`server`] — the inbound engine: [`server::serve_lines`] drives
//!   an accept loop plus per-connection `\x01` line-protocol state
//!   machines for any [`server::LineService`]; connection limits,
//!   idle reaping, pipelining with strict reply ordering.
//! * [`client`] — the outbound engine: [`client::NetDriver`]
//!   multiplexes every router exchange (scatter fan-outs, health
//!   probes, rebalance streams) on one thread with true end-to-end
//!   per-request deadlines.
//!
//! Shared state follows the same `crate::sync` shim discipline as the
//! rest of the concurrency core (PR 6): locks, atomics and channels
//! come from [`crate::sync`], so the queues between reactor threads
//! and their callers stay model-checkable; the reactor loops
//! themselves are real named OS threads (one per server, one driver),
//! not per-connection threads.

pub mod client;
pub mod server;
pub mod sys;
pub mod timer;
