//! Context generation — paper Algorithm 3 / §3.4.
//!
//! For each retrieved address of a query entity, record the first `n`
//! upward (ancestor) and downward (descendant) hierarchical relationship
//! nodes and render them into the template fused into the LLM prompt
//! ("the upward hierarchical relationship of entity A are: B, C and D").

use std::collections::BTreeSet;

use crate::forest::traverse::{ancestors, descendants_with_depth};
use crate::forest::{EntityAddress, Forest};

/// Direction of a hierarchical relationship fact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Direction {
    /// Related node is an ancestor of the entity.
    Up,
    /// Related node is a descendant of the entity.
    Down,
}

/// One (entity, related-node) hierarchical fact.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ContextFact {
    pub entity: String,
    pub related: String,
    pub direction: Direction,
    /// Tree the relationship was found in.
    pub tree: u32,
    /// Hierarchy distance (1 = parent/child).
    pub distance: u8,
}

impl ContextFact {
    /// Render the fact as a prompt sentence.
    pub fn render(&self) -> String {
        match self.direction {
            Direction::Up => format!(
                "{} is under {} (level {}, tree {})",
                self.entity, self.related, self.distance, self.tree
            ),
            Direction::Down => format!(
                "{} contains {} (level {}, tree {})",
                self.entity, self.related, self.distance, self.tree
            ),
        }
    }
}

/// The assembled context for one query entity.
#[derive(Clone, Debug, Default)]
pub struct Context {
    pub facts: Vec<ContextFact>,
}

impl Context {
    /// Render the whole context block for the prompt.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.facts {
            out.push_str(&f.render());
            out.push('\n');
        }
        out
    }

    /// Number of facts.
    pub fn len(&self) -> usize {
        self.facts.len()
    }

    /// True if no facts.
    pub fn is_empty(&self) -> bool {
        self.facts.is_empty()
    }

    /// Merge another context (multi-entity queries).
    pub fn merge(&mut self, other: Context) {
        self.facts.extend(other.facts);
    }

    /// All related-node names (deduped) — what the judge checks recall
    /// against.
    pub fn related_set(&self) -> BTreeSet<String> {
        self.facts.iter().map(|f| f.related.clone()).collect()
    }
}

/// Algorithm 3: walk every address of `entity`, collecting the first `n`
/// upward and the descendants within `n` levels downward.
pub fn generate_context(
    forest: &Forest,
    entity: &str,
    addresses: &[EntityAddress],
    n: usize,
) -> Context {
    let mut facts = Vec::new();
    let mut seen: BTreeSet<(String, Direction, u32)> = BTreeSet::new();
    for &addr in addresses {
        for (dist, anc) in ancestors(forest, addr, n).into_iter().enumerate() {
            let name = forest.entity_name(anc).to_string();
            if seen.insert((name.clone(), Direction::Up, addr.tree)) {
                facts.push(ContextFact {
                    entity: entity.to_string(),
                    related: name,
                    direction: Direction::Up,
                    tree: addr.tree,
                    distance: dist as u8 + 1,
                });
            }
        }
        for (desc, dist) in descendants_with_depth(forest, addr, n) {
            let name = forest.entity_name(desc).to_string();
            if seen.insert((name.clone(), Direction::Down, addr.tree)) {
                facts.push(ContextFact {
                    entity: entity.to_string(),
                    related: name,
                    direction: Direction::Down,
                    tree: addr.tree,
                    distance: dist as u8,
                });
            }
        }
    }
    Context { facts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    /// t0: hospital -> cardiology -> icu -> bed9 ; t1: clinic -> cardiology
    fn forest() -> Forest {
        let mut f = Forest::new();
        let h = f.intern("hospital");
        let c = f.intern("cardiology");
        let i = f.intern("icu");
        let b = f.intern("bed9");
        let cl = f.intern("clinic");
        let mut t0 = Tree::with_root(h);
        let cn = t0.add_child(0, c);
        let im = t0.add_child(cn, i);
        t0.add_child(im, b);
        f.add_tree(t0);
        let mut t1 = Tree::with_root(cl);
        t1.add_child(0, c);
        f.add_tree(t1);
        f
    }

    #[test]
    fn collects_up_and_down_within_n() {
        let f = forest();
        let card = f.entity_id("cardiology").unwrap();
        let addrs = f.scan_addresses(card);
        let ctx = generate_context(&f, "cardiology", &addrs, 2);

        let ups: Vec<&str> = ctx
            .facts
            .iter()
            .filter(|x| x.direction == Direction::Up)
            .map(|x| x.related.as_str())
            .collect();
        // tree 0 ancestor: hospital; tree 1 ancestor: clinic
        assert!(ups.contains(&"hospital"));
        assert!(ups.contains(&"clinic"));

        let downs: Vec<&str> = ctx
            .facts
            .iter()
            .filter(|x| x.direction == Direction::Down)
            .map(|x| x.related.as_str())
            .collect();
        assert!(downs.contains(&"icu"));
        assert!(downs.contains(&"bed9"), "2 levels down included");
    }

    #[test]
    fn n_limits_depth() {
        let f = forest();
        let card = f.entity_id("cardiology").unwrap();
        let addrs = f.scan_addresses(card);
        let ctx = generate_context(&f, "cardiology", &addrs, 1);
        let downs: Vec<&str> = ctx
            .facts
            .iter()
            .filter(|x| x.direction == Direction::Down)
            .map(|x| x.related.as_str())
            .collect();
        assert_eq!(downs, vec!["icu"], "bed9 is 2 levels down");
    }

    #[test]
    fn distances_recorded() {
        let f = forest();
        let card = f.entity_id("cardiology").unwrap();
        let addrs = f.scan_addresses(card);
        let ctx = generate_context(&f, "cardiology", &addrs, 3);
        let bed = ctx.facts.iter().find(|x| x.related == "bed9").unwrap();
        assert_eq!(bed.distance, 2);
        assert_eq!(bed.direction, Direction::Down);
    }

    #[test]
    fn render_contains_relations() {
        let f = forest();
        let icu = f.entity_id("icu").unwrap();
        let addrs = f.scan_addresses(icu);
        let ctx = generate_context(&f, "icu", &addrs, 2);
        let text = ctx.render();
        assert!(text.contains("icu is under cardiology"));
        assert!(text.contains("icu contains bed9"));
    }

    #[test]
    fn empty_addresses_empty_context() {
        let f = forest();
        let ctx = generate_context(&f, "ghost", &[], 3);
        assert!(ctx.is_empty());
    }

    #[test]
    fn merge_concatenates() {
        let f = forest();
        let icu = f.entity_id("icu").unwrap();
        let a = f.scan_addresses(icu);
        let mut c1 = generate_context(&f, "icu", &a, 1);
        let c2 = generate_context(&f, "icu", &a, 2);
        let total = c1.len() + c2.len();
        c1.merge(c2);
        assert_eq!(c1.len(), total);
    }
}
