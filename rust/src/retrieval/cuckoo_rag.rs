//! Cuckoo Filter T-RAG — the paper's system (§4.2). At build time every
//! entity's full address list is packed into a block linked list and
//! indexed by the improved Cuckoo Filter; at query time one O(1) filter
//! lookup replaces the forest traversal entirely. Temperatures are bumped
//! on hit and buckets re-sorted in [`Retriever::maintain`] (§3.1).

use std::sync::Arc;

use crate::filter::cuckoo::{CuckooConfig, CuckooFilter};
use crate::filter::fingerprint::entity_key;
use crate::forest::{EntityAddress, Forest};
use crate::rag::config::KeyPartition;
use crate::retrieval::Retriever;

/// The Cuckoo-Filter-indexed retriever.
pub struct CuckooTRag {
    forest: Arc<Forest>,
    cf: CuckooFilter,
    /// When set, only keys whose replica set contains this backend are
    /// indexed (and dynamic updates for other keys are rejected).
    partition: Option<KeyPartition>,
}

impl CuckooTRag {
    /// Index a forest with the paper's default filter parameters.
    pub fn new(forest: Arc<Forest>) -> Self {
        Self::with_config(forest, CuckooConfig::default())
    }

    /// Index with custom filter parameters (ablations).
    pub fn with_config(forest: Arc<Forest>, cfg: CuckooConfig) -> Self {
        Self::with_partition(forest, cfg, None)
    }

    /// Index with custom filter parameters, keeping only the keys the
    /// given [`KeyPartition`] assigns to this backend (`None` = index
    /// the whole forest). The skipped keys never touch the filter or
    /// the block arena, so a partitioned backend's index memory is
    /// roughly `R/N` of a full one.
    pub fn with_partition(
        forest: Arc<Forest>,
        cfg: CuckooConfig,
        partition: Option<KeyPartition>,
    ) -> Self {
        let mut cf = CuckooFilter::new(cfg);
        // One forest pass builds every entity's address list, then each
        // list is inserted behind its fingerprint.
        let table = forest.address_table();
        for (id, addrs) in table {
            let key = entity_key(forest.entity_name(id));
            if partition.as_ref().map_or(true, |p| p.owns(key)) {
                cf.insert(key, &addrs);
            }
        }
        CuckooTRag { forest, cf, partition }
    }

    /// True when this retriever must index `key` (no partition = all).
    fn owns(&self, key: u64) -> bool {
        self.partition.as_ref().map_or(true, |p| p.owns(key))
    }

    /// Access the underlying filter (benches/inspection).
    pub fn filter(&self) -> &CuckooFilter {
        &self.cf
    }

    /// Mutable access (benches that need to reconfigure).
    pub fn filter_mut(&mut self) -> &mut CuckooFilter {
        &mut self.cf
    }

    /// The forest this retriever indexes.
    pub fn forest(&self) -> &Arc<Forest> {
        &self.forest
    }

    /// Dynamic update: register a newly added occurrence of an entity
    /// (inserts the entity if unknown). Returns `false` when a key
    /// partition excludes the entity from this backend.
    pub fn add_occurrence(&mut self, entity: &str, addr: EntityAddress) -> bool {
        let key = entity_key(entity);
        if !self.owns(key) {
            return false;
        }
        if !self.cf.push_address(key, addr) {
            self.cf.insert(key, &[addr]);
        }
        true
    }

    /// Dynamic update: remove an entity entirely (paper Algorithm 2).
    /// Un-owned keys are a no-op `false` — a partitioned backend never
    /// stored them, and probing the filter anyway could delete a
    /// fingerprint-colliding entry it *does* own.
    pub fn remove_entity(&mut self, entity: &str) -> bool {
        let key = entity_key(entity);
        self.owns(key) && self.cf.delete(key)
    }
}

impl Retriever for CuckooTRag {
    fn name(&self) -> &'static str {
        "CF T-RAG"
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        match self.cf.lookup(entity_key(entity)) {
            Some(hit) => self.cf.addresses(hit),
            None => Vec::new(),
        }
    }

    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        if let Some(hit) = self.cf.lookup(entity_key(entity)) {
            out.extend(self.cf.addresses_iter(hit));
        }
    }

    fn maintain(&mut self) {
        self.cf.maintain();
    }

    fn reindex(&mut self, forest: Arc<Forest>, new_trees: &[u32]) {
        // Incremental (the paper's dynamic-update story): only the new
        // trees' addresses are inserted/appended; the existing filter
        // state — including temperatures — is untouched.
        for &t in new_trees {
            let tree = forest.tree(t);
            for idx in tree.indices() {
                let name = forest.entity_name(tree.entity(idx));
                let key = entity_key(name);
                if !self.owns(key) {
                    continue; // another replica set's key
                }
                let addr = EntityAddress::new(t, idx);
                if !self.cf.push_address(key, addr) {
                    self.cf.insert(key, &[addr]);
                }
            }
        }
        self.forest = forest;
    }

    fn index_bytes(&self) -> usize {
        self.cf.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let a = f.intern("alpha");
        let b = f.intern("beta");
        let c = f.intern("gamma");
        let mut t0 = Tree::with_root(a);
        t0.add_child(0, b);
        t0.add_child(0, c);
        f.add_tree(t0);
        let mut t1 = Tree::with_root(b);
        t1.add_child(0, a);
        f.add_tree(t1);
        Arc::new(f)
    }

    #[test]
    fn agrees_with_scan() {
        let f = forest();
        let mut r = CuckooTRag::new(f.clone());
        for name in ["alpha", "beta", "gamma", "missing"] {
            let mut got = r.find(name);
            got.sort();
            let mut want = f
                .entity_id(name)
                .map(|id| f.scan_addresses(id))
                .unwrap_or_default();
            want.sort();
            assert_eq!(got, want, "{name}");
        }
    }

    #[test]
    fn temperatures_rise_and_sorting_runs() {
        let f = forest();
        let mut r = CuckooTRag::new(f);
        for _ in 0..5 {
            r.find("alpha");
        }
        r.maintain();
        let key = entity_key("alpha");
        assert_eq!(r.filter().temperature(key), Some(5));
    }

    #[test]
    fn dynamic_add_and_remove() {
        let f = forest();
        let mut r = CuckooTRag::new(f);
        r.add_occurrence("delta", EntityAddress::new(5, 0));
        assert_eq!(r.find("delta").len(), 1);
        r.add_occurrence("delta", EntityAddress::new(6, 3));
        assert_eq!(r.find("delta").len(), 2);
        assert!(r.remove_entity("delta"));
        assert!(r.find("delta").is_empty());
    }

    #[test]
    fn index_memory_reported() {
        let r = CuckooTRag::new(forest());
        assert!(r.index_bytes() > 0);
    }

    #[test]
    fn partition_excludes_unowned_keys() {
        use crate::rag::config::KeyPartition;

        let f = forest();
        let backends = ["a:1", "b:2"];
        let parts: Vec<CuckooTRag> = (0..backends.len())
            .map(|i| {
                CuckooTRag::with_partition(
                    f.clone(),
                    CuckooConfig::default(),
                    Some(KeyPartition::new(backends, i, 1).unwrap()),
                )
            })
            .collect();
        let mut parts = parts;
        for name in ["alpha", "beta", "gamma"] {
            let key = entity_key(name);
            let holders: usize = parts
                .iter_mut()
                .map(|p| usize::from(!p.find(name).is_empty()))
                .sum();
            assert_eq!(holders, 1, "{name} held by {holders} backends");
            // dynamic updates follow the same ownership rule
            for (i, p) in parts.iter_mut().enumerate() {
                let owns = KeyPartition::new(backends, i, 1)
                    .unwrap()
                    .owns(key);
                assert_eq!(
                    p.add_occurrence(name, EntityAddress::new(9, 0)),
                    owns,
                    "{name} insert on backend {i}"
                );
                if !owns {
                    assert!(!p.remove_entity(name), "unowned delete no-ops");
                }
            }
        }
    }
}
