//! Deadline bookkeeping for the reactor loops.
//!
//! A sorted map of `(Instant, seq) → token` rather than a hashed
//! timer wheel: the loops here carry at most a few entries per
//! connection/in-flight op, and what they need from the structure is
//! an **exact** next-deadline (to bound the poll timeout, so an idle
//! loop sleeps precisely until the earliest deadline instead of
//! ticking) and **free cancellation**. Both fall out of a `BTreeMap`;
//! a wheel would buy O(1) insert at the cost of tick quantization and
//! explicit cancel lists, which nothing at this fan-in needs.
//!
//! Cancellation is lazy: owners do not remove entries when a deadline
//! becomes irrelevant (the connection closed, the request completed,
//! the idle clock was pushed back by traffic). A fired token is only a
//! *hint* — the owner re-checks its own state and either acts or
//! re-arms. This keeps the hot paths free of timer bookkeeping.

use std::time::Instant;

/// Min-ordered pending deadlines. Not thread-safe by design — each
/// reactor loop owns one and touches it only from the loop thread.
#[derive(Debug, Default)]
pub struct Timers {
    /// `(when, seq) → token`; `seq` disambiguates equal instants.
    queue: std::collections::BTreeMap<(Instant, u64), u64>,
    seq: u64,
}

impl Timers {
    /// An empty deadline set.
    pub fn new() -> Timers {
        Timers::default()
    }

    /// Arm `token` to fire at `when`. Multiple deadlines may be armed
    /// for one token; each fires once (see module doc on laziness).
    pub fn arm(&mut self, when: Instant, token: u64) {
        self.seq += 1;
        self.queue.insert((when, self.seq), token);
    }

    /// The earliest pending deadline, for bounding the poll timeout.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.keys().next().map(|&(when, _)| when)
    }

    /// Pop every deadline at or before `now` into `fired` (appended in
    /// firing order). Returns how many fired.
    pub fn pop_expired(&mut self, now: Instant, fired: &mut Vec<u64>) -> usize {
        let mut n = 0;
        while let Some((&key, &token)) = self.queue.iter().next() {
            if key.0 > now {
                break;
            }
            self.queue.remove(&key);
            fired.push(token);
            n += 1;
        }
        n
    }

    /// Number of pending (possibly stale) deadlines.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether no deadlines are pending.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fires_in_deadline_order_and_tracks_next() {
        let mut t = Timers::new();
        let base = Instant::now();
        t.arm(base + Duration::from_millis(30), 3);
        t.arm(base + Duration::from_millis(10), 1);
        t.arm(base + Duration::from_millis(20), 2);
        assert_eq!(t.next_deadline(), Some(base + Duration::from_millis(10)));

        let mut fired = Vec::new();
        assert_eq!(t.pop_expired(base + Duration::from_millis(25), &mut fired), 2);
        assert_eq!(fired, vec![1, 2]);
        assert_eq!(t.next_deadline(), Some(base + Duration::from_millis(30)));

        assert_eq!(t.pop_expired(base + Duration::from_millis(30), &mut fired), 1);
        assert_eq!(fired, vec![1, 2, 3]);
        assert!(t.is_empty());
        assert_eq!(t.next_deadline(), None);
    }

    #[test]
    fn equal_instants_keep_arm_order() {
        let mut t = Timers::new();
        let when = Instant::now();
        t.arm(when, 10);
        t.arm(when, 20);
        t.arm(when, 30);
        assert_eq!(t.len(), 3);
        let mut fired = Vec::new();
        t.pop_expired(when, &mut fired);
        assert_eq!(fired, vec![10, 20, 30]);
    }

    #[test]
    fn nothing_fires_before_its_time() {
        let mut t = Timers::new();
        let base = Instant::now();
        t.arm(base + Duration::from_secs(60), 1);
        let mut fired = Vec::new();
        assert_eq!(t.pop_expired(base, &mut fired), 0);
        assert!(fired.is_empty());
        assert_eq!(t.len(), 1);
    }
}
