//! Prompt assembly, the deterministic answer generator (LLM stand-in,
//! backed by the rank artifact's attention kernel) and the accuracy judge.

pub mod cache;
pub mod generator;
pub mod judge;
pub mod prompt;

pub use cache::EmbedCache;
pub use generator::{Answer, Generator};
pub use judge::{judge, Judgement};
pub use prompt::Prompt;
