//! Top-k similarity search over the vector store via the score artifact
//! (the L1 Pallas tiled-matmul kernel under the hood).

use crate::error::Result;
use crate::runtime::engine::Engine;
use crate::vector::store::VectorStore;

/// One search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub doc: u32,
    pub score: f32,
}

/// Top-k documents for each query in an embedded batch.
///
/// `q` is `[batch, D]` row-major; returns one hit list per batch row
/// (rows beyond `valid` are skipped — they're batch padding).
pub fn search_topk(
    engine: &dyn Engine,
    store: &VectorStore,
    q: &[f32],
    valid: usize,
    k: usize,
) -> Result<Vec<Vec<Hit>>> {
    let shape = engine.shape();
    let b = shape.batch;
    let per = store.shard_docs();
    let mut best: Vec<Vec<Hit>> = vec![Vec::new(); valid.min(b)];

    for s in 0..store.shards() {
        let scores = engine.score(q, store.shard(s))?;
        for (row, best_row) in best.iter_mut().enumerate() {
            let base = row * per;
            for i in 0..per {
                let doc = (s * per + i) as u32;
                if doc as usize >= store.len() {
                    break; // padding rows
                }
                push_topk(best_row, Hit { doc, score: scores[base + i] }, k);
            }
        }
    }
    for row in &mut best {
        row.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap()
                .then_with(|| a.doc.cmp(&b.doc))
        });
    }
    Ok(best)
}

/// Maintain a bounded top-k list (small k: linear insert is fastest).
fn push_topk(row: &mut Vec<Hit>, hit: Hit, k: usize) {
    if row.len() < k {
        row.push(hit);
        return;
    }
    // replace the current minimum if beaten
    let (mut min_i, mut min_s) = (0usize, f32::INFINITY);
    for (i, h) in row.iter().enumerate() {
        if h.score < min_s {
            min_s = h.score;
            min_i = i;
        }
    }
    if hit.score > min_s {
        row[min_i] = hit;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::corpus_from_texts;
    use crate::runtime::engine::{EngineShape, NativeEngine};
    use crate::text::tokenizer::tokenize_padded;

    fn engine() -> NativeEngine {
        NativeEngine::with_shape(EngineShape {
            batch: 4,
            max_tokens: 16,
            embed_dim: 32,
            shard_docs: 8,
            max_facts: 8,
        })
    }

    fn embed_queries(e: &NativeEngine, qs: &[&str]) -> Vec<f32> {
        let s = e.shape();
        let mut toks = vec![0i32; s.batch * s.max_tokens];
        for (i, q) in qs.iter().enumerate() {
            toks[i * s.max_tokens..(i + 1) * s.max_tokens]
                .copy_from_slice(&tokenize_padded(q, s.max_tokens));
        }
        e.embed(&toks).unwrap()
    }

    #[test]
    fn finds_matching_document() {
        let e = engine();
        let texts = vec![
            "cardiology intensive care unit history".to_string(),
            "logistics and warehouse supply records".to_string(),
            "pediatrics vaccination program overview".to_string(),
            "surgery theatre scheduling notes".to_string(),
            "oncology chemotherapy ward summary".to_string(),
            "radiology imaging suite report".to_string(),
            "neurology outpatient clinic file".to_string(),
            "pharmacy dispensary stock list".to_string(),
            "dermatology skin clinic archive".to_string(),
            "pathology blood bank papers".to_string(),
        ];
        let store = VectorStore::build(&e, corpus_from_texts(&texts)).unwrap();
        let q = embed_queries(&e, &["cardiology intensive care", "pharmacy stock"]);
        let hits = search_topk(&e, &store, &q, 2, 3).unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0][0].doc, 0, "cardiology doc wins: {:?}", hits[0]);
        assert_eq!(hits[1][0].doc, 7, "pharmacy doc wins: {:?}", hits[1]);
        // scores sorted desc
        assert!(hits[0][0].score >= hits[0][1].score);
    }

    #[test]
    fn k_larger_than_corpus() {
        let e = engine();
        let store = VectorStore::build(
            &e,
            corpus_from_texts(&["single doc here".to_string()]),
        )
        .unwrap();
        let q = embed_queries(&e, &["anything"]);
        let hits = search_topk(&e, &store, &q, 1, 10).unwrap();
        assert_eq!(hits[0].len(), 1, "padding never returned");
    }

    #[test]
    fn topk_bounded() {
        let e = engine();
        let texts: Vec<String> =
            (0..20).map(|i| format!("generic document {i}")).collect();
        let store = VectorStore::build(&e, corpus_from_texts(&texts)).unwrap();
        let q = embed_queries(&e, &["generic document"]);
        let hits = search_topk(&e, &store, &q, 1, 5).unwrap();
        assert_eq!(hits[0].len(), 5);
    }
}
