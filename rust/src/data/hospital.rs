//! Synthetic hospital-history dataset — stand-in for the paper's private
//! Chinese hospital-histories corpus (§4.3), matched on the published
//! statistics: ~3,148 distinct entities at the 600-tree scale, heavy
//! entity sharing across trees (every hospital has a cardiology...), and
//! a raw-text path that exercises the §2 pre-processing pipeline.
//!
//! Two outputs per hospital:
//! * **relation tuples** — the fast path for building large forests;
//! * **history paragraphs** — English prose embedding the same relations
//!   through the extraction patterns ("X belongs to Y", "Y contains X",
//!   appositives), so NER -> relate -> filter -> builder reproduces the
//!   same tree (validated by tests).

use crate::data::vocab::{
    DEPARTMENTS, HOSPITAL_FIRST, HOSPITAL_SECOND, MODIFIERS, SUBUNITS,
};
use crate::forest::{builder::build_trees, Forest};
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Clone, Copy, Debug)]
pub struct HospitalConfig {
    /// Number of hospitals (= trees).
    pub trees: usize,
    /// Mean departments per hospital.
    pub depts_per_tree: usize,
    /// Mean sub-units per department.
    pub subunits_per_dept: usize,
    /// Probability a sub-unit gets a deeper nested unit (recursive).
    pub deepen_prob: f64,
    /// Max extra nesting levels below sub-units.
    pub max_extra_depth: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for HospitalConfig {
    fn default() -> Self {
        HospitalConfig {
            trees: 50,
            depts_per_tree: 8,
            subunits_per_dept: 3,
            deepen_prob: 0.45,
            max_extra_depth: 4,
            seed: 0x1405_7174,
        }
    }
}

/// One generated hospital: its name, relation tuples, and history text.
#[derive(Clone, Debug)]
pub struct Hospital {
    pub name: String,
    /// (child, parent) tuples, pre-filtered, tree-shaped.
    pub relations: Vec<(String, String)>,
    /// Raw prose encoding the same relations (pre-processing path).
    pub history: String,
}

/// The full dataset.
#[derive(Clone, Debug)]
pub struct HospitalDataset {
    pub hospitals: Vec<Hospital>,
}

impl HospitalDataset {
    /// Generate deterministically from the config.
    pub fn generate(cfg: HospitalConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut hospitals = Vec::with_capacity(cfg.trees);
        for i in 0..cfg.trees {
            hospitals.push(gen_hospital(&mut rng, &cfg, i));
        }
        HospitalDataset { hospitals }
    }

    /// Build the entity forest from the relation tuples (fast path).
    pub fn build_forest(&self) -> Forest {
        let mut forest = Forest::new();
        for h in &self.hospitals {
            build_trees(&mut forest, &h.relations);
        }
        forest
    }

    /// All hospital history documents (for the vector-search corpus and
    /// the raw-text pre-processing path).
    pub fn documents(&self) -> Vec<String> {
        self.hospitals.iter().map(|h| h.history.clone()).collect()
    }
}

fn hospital_name(rng: &mut Rng, idx: usize) -> String {
    let first = HOSPITAL_FIRST[idx % HOSPITAL_FIRST.len()];
    let second = HOSPITAL_SECOND[(idx / HOSPITAL_FIRST.len()) % HOSPITAL_SECOND.len()];
    let serial = idx / (HOSPITAL_FIRST.len() * HOSPITAL_SECOND.len());
    if serial == 0 {
        format!("{first} {second}")
    } else {
        // enough distinct roots for any tree count
        format!("{first} {second} {}", ordinal(serial, rng))
    }
}

fn ordinal(n: usize, _rng: &mut Rng) -> String {
    format!("campus {n}")
}

fn gen_hospital(rng: &mut Rng, cfg: &HospitalConfig, idx: usize) -> Hospital {
    let name = hospital_name(rng, idx);
    let mut relations: Vec<(String, String)> = Vec::new();
    let mut sentences: Vec<String> = Vec::new();
    sentences.push(format!(
        "{} was founded in {} and has served the region since.",
        title(&name),
        1900 + rng.range(0, 100)
    ));

    // Departments: Zipf-ish — earlier stems are far more common, so the
    // same department names recur across most hospitals.
    let ndepts = jitter(rng, cfg.depts_per_tree);
    let mut chosen: Vec<&str> = Vec::new();
    while chosen.len() < ndepts.min(DEPARTMENTS.len()) {
        // triangular skew toward the head of the list
        let r = (rng.f64() * rng.f64() * DEPARTMENTS.len() as f64) as usize;
        let d = DEPARTMENTS[r.min(DEPARTMENTS.len() - 1)];
        if !chosen.contains(&d) {
            chosen.push(d);
        }
    }

    for dept in chosen {
        relations.push((dept.to_string(), name.clone()));
        match rng.range(0, 3) {
            0 => sentences.push(format!(
                "The {} belongs to {}.",
                dept,
                title(&name)
            )),
            1 => sentences.push(format!(
                "{} contains the {}.",
                title(&name),
                dept
            )),
            _ => sentences.push(format!(
                "The {}, a unit of {}, is well regarded.",
                dept,
                title(&name)
            )),
        }

        // sub-units below the department
        let nsub = jitter(rng, cfg.subunits_per_dept);
        for _ in 0..nsub {
            let sub = subunit_name(rng, dept);
            relations.push((sub.clone(), dept.to_string()));
            sentences.push(format!(
                "The {} belongs to the {}.",
                sub, dept
            ));
            // optional deeper nesting
            let mut parent = sub;
            let mut depth = 0;
            while depth < cfg.max_extra_depth && rng.chance(cfg.deepen_prob) {
                let child = subunit_name(rng, &parent);
                relations.push((child.clone(), parent.clone()));
                sentences.push(format!(
                    "The {child} is part of the {parent}."
                ));
                parent = child;
                depth += 1;
            }
        }
    }

    Hospital {
        name,
        relations,
        history: sentences.join(" "),
    }
}

/// Compose a sub-unit name. Includes the parent's first word often enough
/// to keep names meaningful but distinct.
fn subunit_name(rng: &mut Rng, parent: &str) -> String {
    let m = MODIFIERS[rng.range(0, MODIFIERS.len())];
    let s = SUBUNITS[rng.range(0, SUBUNITS.len())];
    let parent_head = parent.split_whitespace().next().unwrap_or("unit");
    if rng.chance(0.5) {
        format!("{m} {parent_head} {s}")
    } else {
        format!("{m} {s}")
    }
}

fn jitter(rng: &mut Rng, mean: usize) -> usize {
    let lo = (mean as f64 * 0.5).max(1.0) as usize;
    let hi = (mean as f64 * 1.5).max(2.0) as usize;
    rng.range(lo, hi + 1)
}

fn title(s: &str) -> String {
    s.split_whitespace()
        .map(|w| {
            let mut c = w.chars();
            match c.next() {
                Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
                None => String::new(),
            }
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = HospitalDataset::generate(HospitalConfig::default());
        let b = HospitalDataset::generate(HospitalConfig::default());
        assert_eq!(a.hospitals[0].relations, b.hospitals[0].relations);
        assert_eq!(a.hospitals[0].history, b.hospitals[0].history);
    }

    #[test]
    fn tree_count_matches() {
        let cfg = HospitalConfig { trees: 20, ..HospitalConfig::default() };
        let ds = HospitalDataset::generate(cfg);
        assert_eq!(ds.hospitals.len(), 20);
        let f = ds.build_forest();
        assert_eq!(f.len(), 20, "one tree per hospital");
    }

    #[test]
    fn entities_shared_across_trees() {
        let cfg = HospitalConfig { trees: 30, ..HospitalConfig::default() };
        let f = HospitalDataset::generate(cfg).build_forest();
        // cardiology (head of the stem list) should occur in many trees
        let card = f.entity_id("cardiology").expect("cardiology exists");
        let occurrences = f.scan_addresses(card).len();
        assert!(occurrences > 10, "only {occurrences} occurrences");
    }

    #[test]
    fn forest_depth_supports_unanswerable_tail() {
        // context level n=3; the accuracy plateau needs some entities
        // deeper than 3 (see data::gold) — ensure depth exists.
        let f = HospitalDataset::generate(HospitalConfig::default()).build_forest();
        assert!(f.stats().max_depth >= 4, "max depth {}", f.stats().max_depth);
    }

    #[test]
    fn paper_scale_distinct_entities() {
        // 600 trees should give a few thousand distinct entities
        let cfg = HospitalConfig { trees: 600, ..HospitalConfig::default() };
        let f = HospitalDataset::generate(cfg).build_forest();
        let distinct = f.stats().distinct_entities;
        assert!(
            (2000..12_000).contains(&distinct),
            "distinct entities {distinct} out of plausible range"
        );
    }

    #[test]
    fn history_text_regenerates_same_tree_shape() {
        use crate::nlp::{filter::filter_relations, relate};
        let cfg = HospitalConfig { trees: 3, ..HospitalConfig::default() };
        let ds = HospitalDataset::generate(cfg);
        for h in &ds.hospitals {
            let extracted = relate::extract_pairs(&h.history);
            let filtered = filter_relations(&extracted);
            // every direct generator relation should be recoverable
            let missing: Vec<_> = h
                .relations
                .iter()
                .filter(|r| !filtered.contains(r))
                .collect();
            assert!(
                missing.len() * 10 <= h.relations.len(),
                "{} of {} relations lost in text roundtrip: {missing:?}",
                missing.len(),
                h.relations.len()
            );
        }
    }
}
