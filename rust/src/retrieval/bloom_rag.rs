//! Bloom Filter T-RAG (paper §4.1): every node carries a Bloom filter of
//! its subtree's entities; a descent is pruned the moment a filter says
//! the entity cannot be below. Still traverses, but skips cold subtrees.

use std::sync::Arc;

use crate::filter::fingerprint::entity_key;
use crate::filter::tree_bloom::BloomForest;
use crate::forest::{EntityAddress, Forest, NodeIdx};
use crate::retrieval::{Retriever, SharedRetriever};

/// Bloom-pruned retriever.
pub struct BloomTRag {
    forest: Arc<Forest>,
    blooms: BloomForest,
    fp_rate: f64,
    bytes: usize,
}

impl BloomTRag {
    /// Build subtree blooms over `forest` at the given FP rate.
    pub fn new(forest: Arc<Forest>, fp_rate: f64) -> Self {
        let blooms = BloomForest::build(&forest, fp_rate);
        let bytes = blooms.memory_bytes();
        BloomTRag { forest, blooms, fp_rate, bytes }
    }

    fn descend(
        &self,
        tree_idx: u32,
        node: NodeIdx,
        id: crate::forest::EntityId,
        key: u64,
        out: &mut Vec<EntityAddress>,
    ) {
        let tree = self.forest.tree(tree_idx);
        if tree.entity(node) == id {
            out.push(EntityAddress::new(tree_idx, node));
        }
        for &c in &tree.node(node).children {
            // prune: child's bloom covers child + its descendants
            if self.blooms.might_contain(tree_idx, c, key) {
                self.descend(tree_idx, c, id, key, out);
            }
        }
    }
}

impl SharedRetriever for BloomTRag {
    fn name(&self) -> &'static str {
        "BF T-RAG"
    }

    /// The whole search through `&self`: blooms and heights are
    /// written once at build time, so any number of threads descend in
    /// parallel with no synchronization (shared via `ArcRetriever`).
    fn find_shared(&self, entity: &str, out: &mut Vec<EntityAddress>) {
        let Some(id) = self.forest.entity_id(entity) else {
            return;
        };
        let key = entity_key(entity);
        for t in 0..self.forest.len() as u32 {
            if self.blooms.might_contain(t, 0, key) {
                self.descend(t, 0, id, key, out);
            }
        }
    }

    fn rebuild(&self, forest: Arc<Forest>) -> Self {
        Self::new(forest, self.fp_rate)
    }

    fn index_bytes(&self) -> usize {
        self.bytes
    }
}

impl Retriever for BloomTRag {
    fn name(&self) -> &'static str {
        SharedRetriever::name(self)
    }

    fn find(&mut self, entity: &str) -> Vec<EntityAddress> {
        let mut out = Vec::new();
        self.find_shared(entity, &mut out);
        out
    }

    fn find_into(&mut self, entity: &str, out: &mut Vec<EntityAddress>) {
        self.find_shared(entity, out);
    }

    fn reindex(&mut self, forest: Arc<Forest>, _new_trees: &[u32]) {
        // per-node blooms are subtree-global: rebuild (the update cost
        // the CF design avoids — measured by benches/updates.rs)
        self.blooms = BloomForest::build(&forest, self.fp_rate);
        self.bytes = self.blooms.memory_bytes();
        self.forest = forest;
    }

    fn index_bytes(&self) -> usize {
        self.bytes
    }
}

#[cfg(test)]
mod shared_tests {
    use super::*;
    use crate::retrieval::{ArcRetriever, ConcurrentRetriever};
    use std::sync::Arc;

    #[test]
    fn shared_find_agrees_across_threads() {
        let f = super::tests::forest();
        let shared = Arc::new(ArcRetriever::new(BloomTRag::new(f.clone(), 0.01)));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let shared = shared.clone();
                let f = f.clone();
                s.spawn(move || {
                    let mut out = Vec::new();
                    for name in ["h", "a", "b", "c", "d", "zzz"] {
                        out.clear();
                        shared.find_concurrent(name, &mut out);
                        let want = f
                            .entity_id(name)
                            .map(|id| f.scan_addresses(id))
                            .unwrap_or_default();
                        assert_eq!(out, want, "{name}");
                    }
                });
            }
        });
        assert!(shared.index_bytes() > 0);
        assert_eq!(ConcurrentRetriever::name(shared.as_ref()), "BF T-RAG");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::Tree;

    pub(super) fn forest() -> Arc<Forest> {
        let mut f = Forest::new();
        let names: Vec<_> = ["h", "a", "b", "c", "d"]
            .iter()
            .map(|n| f.intern(n))
            .collect();
        let mut t = Tree::with_root(names[0]);
        let a = t.add_child(0, names[1]);
        t.add_child(0, names[2]);
        t.add_child(a, names[3]);
        t.add_child(a, names[4]);
        f.add_tree(t);
        // second tree without "c"
        let mut t2 = Tree::with_root(names[2]);
        t2.add_child(0, names[4]);
        f.add_tree(t2);
        Arc::new(f)
    }

    #[test]
    fn agrees_with_scan() {
        let f = forest();
        let mut r = BloomTRag::new(f.clone(), 0.01);
        for name in ["h", "a", "b", "c", "d", "zzz"] {
            let want = f
                .entity_id(name)
                .map(|id| f.scan_addresses(id))
                .unwrap_or_default();
            assert_eq!(r.find(name), want, "{name}");
        }
    }

    #[test]
    fn reports_index_memory() {
        let r = BloomTRag::new(forest(), 0.01);
        // qualified: BloomTRag reports the same bytes through both the
        // owned and the shared retriever traits
        assert!(Retriever::index_bytes(&r) > 0);
        assert_eq!(Retriever::index_bytes(&r), SharedRetriever::index_bytes(&r));
    }
}
