//! Timing and summary statistics for the benchmark harness and metrics.
//!
//! Criterion is unavailable offline, so the repo owns its measurement
//! substrate: wall-clock timers, Welford online moments, and percentile
//! summaries used by every bench target and the coordinator's latency
//! histograms.

use std::time::{Duration, Instant};

/// Simple wall-clock timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// New empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Count of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 if empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (0 if < 2 observations).
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize a sample (empty sample => all zeros).
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                count: 0, mean: 0.0, stddev: 0.0, min: 0.0,
                p50: 0.0, p90: 0.0, p99: 0.0, max: 0.0,
            };
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            count: xs.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=1.0).contains(&q), "q out of range: {q}");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fixed-bucket latency histogram (log-spaced), cheap enough for the
/// coordinator hot path: one atomic-free increment per observation.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [BASE * GROWTH^i, BASE * GROWTH^(i+1)) seconds
    buckets: Vec<u64>,
    overflow: u64,
    count: u64,
    sum: f64,
}

const HIST_BASE: f64 = 1e-7; // 100 ns
const HIST_GROWTH: f64 = 1.5;
const HIST_BUCKETS: usize = 64;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// New empty histogram covering ~100ns ..= ~3000s.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; HIST_BUCKETS],
            overflow: 0,
            count: 0,
            sum: 0.0,
        }
    }

    /// Record one latency in seconds.
    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        self.sum += secs;
        if secs < HIST_BASE {
            self.buckets[0] += 1;
            return;
        }
        let idx = ((secs / HIST_BASE).ln() / HIST_GROWTH.ln()) as usize;
        if idx < HIST_BUCKETS {
            self.buckets[idx] += 1;
        } else {
            self.overflow += 1;
        }
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Approximate quantile (upper bucket edge), in seconds.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return HIST_BASE * HIST_GROWTH.powi(i as i32 + 1);
            }
        }
        f64::INFINITY
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // direct sample variance
        let mean = 5.0;
        let var: f64 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 7.0;
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 100.0).abs() < 1e-12);
        assert!(s.p90 > 89.0 && s.p90 < 92.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_single_value() {
        let s = Summary::of(&[3.5]);
        assert_eq!(s.p50, 3.5);
        assert_eq!(s.p99, 3.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_bracket() {
        let mut h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record(1e-3);
        }
        let p50 = h.quantile(0.5);
        // log-bucketed: true value within one growth factor
        assert!(p50 > 1e-3 / HIST_GROWTH && p50 < 1e-3 * HIST_GROWTH * HIST_GROWTH);
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1e-4);
        b.record(1e-2);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.secs() >= 0.001);
    }
}
