"""L2: the JAX compute graphs behind the request-path artifacts.

Three graphs, each AOT-lowered once by :mod:`compile.aot`:

* :func:`embed`  — token ids -> L2-normalized sentence embedding. Uses a
  deterministic *random-feature* token embedding (sinusoidal features of
  the hashed token id) so no multi-MiB table has to be baked into HLO
  text; mean-pools over non-padding tokens; finishes with the fused
  Pallas layer-norm and an L2 normalize. Bag-of-words random projection:
  cosine similarity between outputs approximates token overlap, which is
  exactly what deterministic vector search needs.
* :func:`score`  — Pallas tiled similarity matmul of queries vs a corpus
  shard (see kernels.similarity).
* :func:`rank`   — Pallas masked attention weights of queries over their
  retrieved facts (see kernels.attention).

Everything is shape-static at lowering time; the Rust coordinator batches
requests up to the artifact batch size and pads.

Python never runs at serve time: these functions exist only to be lowered.
"""

import jax
import jax.numpy as jnp

from .kernels.similarity import similarity_scores
from .kernels.attention import attention_weights
from .kernels.layernorm import layer_norm

# ---------------------------------------------------------------------------
# Fixed model hyperparameters (must match rust/src/runtime/artifact.rs).
# ---------------------------------------------------------------------------
EMBED_DIM = 64          # D: embedding dimension
MAX_TOKENS = 32         # L_tok: tokens per text (padded/truncated)
SHARD_DOCS = 1024       # N: corpus shard size for the score artifact
MAX_FACTS = 64          # L_fact: facts per request for the rank artifact
BATCH = 8               # B: artifact batch size
PAD_ID = 0              # token id reserved for padding

# Deterministic feature constants, generated once at import from a fixed
# seed; they are baked into the HLO as ~KiB-scale constants.
_key = jax.random.PRNGKey(20_25)
_k_freq, _k_phase, _k_gamma = jax.random.split(_key, 3)
FREQ = jax.random.uniform(_k_freq, (EMBED_DIM,), jnp.float32, 0.05, 2.0)
PHASE = jax.random.uniform(_k_phase, (EMBED_DIM,), jnp.float32, 0.0, 6.2831853)
GAMMA = 1.0 + 0.1 * jax.random.normal(_k_gamma, (EMBED_DIM,), jnp.float32)
BETA = jnp.zeros((EMBED_DIM,), jnp.float32)


def token_features(ids):
    """Deterministic random-feature embedding of token ids.

    Args:
      ids: [...] int32 hashed token ids (PAD_ID = padding).

    Returns:
      [..., EMBED_DIM] float32 — near-orthogonal unit-scale features per id.
    """
    x = ids.astype(jnp.float32)[..., None]  # [..., 1]
    # sin(id * freq + phase): distinct ids land on effectively independent
    # phases, giving random-projection behaviour without a lookup table.
    return jnp.sin(x * FREQ + PHASE)


def embed(tokens):
    """Token ids -> L2-normalized sentence embeddings.

    Args:
      tokens: [B, MAX_TOKENS] int32, PAD_ID-padded.

    Returns:
      [B, EMBED_DIM] float32, unit L2 norm (zero rows for empty inputs).
    """
    feats = token_features(tokens)                      # [B, L, D]
    mask = (tokens != PAD_ID).astype(jnp.float32)       # [B, L]
    summed = jnp.einsum("bld,bl->bd", feats, mask)
    count = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)
    pooled = summed / count                             # [B, D] mean pool
    normed = layer_norm(pooled, GAMMA, BETA)            # fused Pallas LN
    norm = jnp.sqrt(jnp.sum(normed * normed, axis=-1, keepdims=True))
    return normed / jnp.maximum(norm, 1e-12)


def score(q, docs):
    """Similarity scores of query embeddings vs one corpus shard.

    Args:
      q:    [B, EMBED_DIM] float32.
      docs: [SHARD_DOCS, EMBED_DIM] float32.

    Returns:
      [B, SHARD_DOCS] float32.
    """
    return similarity_scores(q, docs)


def rank(q, facts, lens):
    """Attention weights of each query over its retrieved facts.

    Args:
      q:     [B, EMBED_DIM] float32 query embeddings.
      facts: [B, MAX_FACTS, EMBED_DIM] float32 fact embeddings, zero padded.
      lens:  [B] int32 valid-fact counts.

    Returns:
      [B, MAX_FACTS] float32 weights.
    """
    return attention_weights(q, facts, lens)


# Example input specs for AOT lowering (shape/dtype only, no data).
def embed_specs():
    return (jax.ShapeDtypeStruct((BATCH, MAX_TOKENS), jnp.int32),)


def score_specs():
    return (
        jax.ShapeDtypeStruct((BATCH, EMBED_DIM), jnp.float32),
        jax.ShapeDtypeStruct((SHARD_DOCS, EMBED_DIM), jnp.float32),
    )


def rank_specs():
    return (
        jax.ShapeDtypeStruct((BATCH, EMBED_DIM), jnp.float32),
        jax.ShapeDtypeStruct((BATCH, MAX_FACTS, EMBED_DIM), jnp.float32),
        jax.ShapeDtypeStruct((BATCH,), jnp.int32),
    )
