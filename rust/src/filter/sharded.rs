//! Sharded Cuckoo Filter: the key space partitioned across N independent
//! [`CuckooFilter`] shards so retrieval scales with reader threads.
//!
//! # Design
//!
//! Each shard owns a full filter — buckets, temperatures, block arena —
//! behind its own [`std::sync::RwLock`]. A key's shard is chosen by the
//! *high* bits of the secondary hash ([`shard_index`]), independent of
//! the bits that pick the in-shard bucket and the fingerprint, so load
//! spreads uniformly and shards never need to coordinate: an operation
//! touches exactly one shard.
//!
//! # Locking invariants
//!
//! * **Lookups take only the shard read lock.** The underlying filter's
//!   [`CuckooFilter::lookup_shared`] works through `&self`: temperature
//!   bumps are relaxed `AtomicU32` increments and dirty-bucket flags
//!   relaxed `AtomicBool` stores, so any number of readers proceed in
//!   parallel (per shard and across shards).
//! * **Structural mutations take the shard write lock**: insert, delete,
//!   push_address, and `maintain` (per-shard bucket re-sort). A write
//!   lock on one shard never blocks readers of another.
//! * **Block-list reads happen under the same read-lock hold** as the
//!   lookup that produced the head — addresses are copied out before the
//!   guard drops, so a concurrent delete/expand on the shard can never
//!   invalidate a head the caller still holds.
//! * Lock poisoning (a writer panicking mid-mutation) propagates to all
//!   later accessors via `unwrap`, which is the safe failure mode: the
//!   shard's invariants can no longer be trusted.
//!
//! Aggregate accessors (`len`, `stats`, `memory_bytes`) lock shards one
//! at a time; they are monitoring APIs and make no cross-shard atomicity
//! promise.

use std::sync::RwLock;

use crate::filter::cuckoo::{CuckooConfig, CuckooFilter, CuckooStats};
use crate::filter::fingerprint::shard_index;
use crate::forest::EntityAddress;

/// A Cuckoo Filter partitioned across independent, individually locked
/// shards. All operations take `&self`; see the module docs for which
/// take read vs write locks.
#[derive(Debug)]
pub struct ShardedCuckooFilter {
    shards: Vec<RwLock<CuckooFilter>>,
}

impl ShardedCuckooFilter {
    /// Build with `nshards` shards (rounded up to a power of two). The
    /// configured `initial_buckets` is the *total* across shards, so a
    /// sharded and an unsharded filter of the same config start at the
    /// same capacity.
    pub fn new(cfg: CuckooConfig, nshards: usize) -> Self {
        let n = nshards.max(1).next_power_of_two();
        let shards = (0..n)
            .map(|i| {
                RwLock::new(CuckooFilter::new(CuckooConfig {
                    initial_buckets: (cfg.initial_buckets / n).max(1),
                    // decorrelate eviction choices across shards
                    seed: cfg.seed ^ (0x9E37_79B9_7F4A_7C15u64
                        .wrapping_mul(i as u64 + 1)),
                    ..cfg
                }))
            })
            .collect();
        ShardedCuckooFilter { shards }
    }

    /// Number of shards (power of two).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<CuckooFilter> {
        &self.shards[shard_index(key, self.shards.len())]
    }

    /// Insert an entity with its addresses (shard write lock). Duplicate
    /// keys are rejected, matching [`CuckooFilter::insert`].
    pub fn insert(&self, key: u64, addrs: &[EntityAddress]) -> bool {
        self.shard(key).write().unwrap().insert(key, addrs)
    }

    /// Remove an entity (shard write lock); reclaims its block list.
    pub fn delete(&self, key: u64) -> bool {
        self.shard(key).write().unwrap().delete(key)
    }

    /// Append an address to an existing entity (shard write lock).
    pub fn push_address(&self, key: u64, addr: EntityAddress) -> bool {
        self.shard(key).write().unwrap().push_address(key, addr)
    }

    /// Fingerprint membership probe (shard read lock).
    pub fn contains(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains(key)
    }

    /// Exact membership (shard read lock).
    pub fn contains_exact(&self, key: u64) -> bool {
        self.shard(key).read().unwrap().contains_exact(key)
    }

    /// Lookup: append all addresses of `key` to `out` and return whether
    /// the entity was found. Takes only the shard **read** lock — the
    /// concurrent serving hot path. Addresses are copied out under the
    /// guard, so the returned data is consistent even if a writer
    /// reshapes the shard immediately after.
    pub fn lookup_into(&self, key: u64, out: &mut Vec<EntityAddress>) -> bool {
        let shard = self.shard(key).read().unwrap();
        match shard.lookup_shared(key) {
            Some(hit) => {
                out.extend(shard.addresses_iter(hit));
                true
            }
            None => false,
        }
    }

    /// Lookup returning a fresh `Vec` (`None` on miss). Read lock only.
    pub fn lookup_collect(&self, key: u64) -> Option<Vec<EntityAddress>> {
        let mut out = Vec::new();
        self.lookup_into(key, &mut out).then_some(out)
    }

    /// Temperature of a key, if present (shard read lock; test/bench).
    pub fn temperature(&self, key: u64) -> Option<u32> {
        self.shard(key).read().unwrap().temperature(key)
    }

    /// Re-sort dirty buckets by temperature, one shard at a time (shard
    /// write lock). Readers of other shards are never blocked, and each
    /// shard is writer-locked only for its own sort.
    pub fn maintain(&self) {
        for shard in &self.shards {
            shard.write().unwrap().maintain();
        }
    }

    /// Entries stored across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// True if no shard holds entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregate load factor: total entries / total slots.
    pub fn load_factor(&self) -> f64 {
        let (len, slots) = self.shards.iter().fold((0usize, 0usize), |acc, s| {
            let g = s.read().unwrap();
            (acc.0 + g.len(), acc.1 + g.buckets() * g.slots_per_bucket())
        });
        if slots == 0 {
            0.0
        } else {
            len as f64 / slots as f64
        }
    }

    /// Counters summed across shards.
    pub fn stats(&self) -> CuckooStats {
        let mut total = CuckooStats::default();
        for shard in &self.shards {
            total.merge(shard.read().unwrap().stats());
        }
        total
    }

    /// Approximate heap bytes across all shards.
    pub fn memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().memory_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::fingerprint::entity_key;

    fn key(i: u64) -> u64 {
        entity_key(&format!("sharded-{i}"))
    }

    fn addrs(n: u32) -> Vec<EntityAddress> {
        (0..n).map(|i| EntityAddress::new(i, i)).collect()
    }

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 3);
        assert_eq!(cf.num_shards(), 4);
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 0);
        assert_eq!(cf.num_shards(), 1);
    }

    #[test]
    fn insert_lookup_delete_roundtrip() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 8);
        for i in 0..2000 {
            assert!(cf.insert(key(i), &addrs(2)), "insert {i}");
        }
        assert_eq!(cf.len(), 2000);
        for i in 0..2000 {
            assert_eq!(cf.lookup_collect(key(i)).as_deref(), Some(&addrs(2)[..]));
        }
        for i in 0..2000 {
            assert!(cf.delete(key(i)), "delete {i}");
        }
        assert!(cf.is_empty());
    }

    #[test]
    fn duplicate_and_missing_semantics_match_unsharded() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        assert!(cf.insert(key(1), &addrs(1)));
        assert!(!cf.insert(key(1), &addrs(3)), "duplicate rejected");
        assert!(!cf.delete(key(2)));
        assert!(!cf.push_address(key(2), EntityAddress::new(0, 0)));
        assert!(cf.push_address(key(1), EntityAddress::new(7, 7)));
        assert_eq!(cf.lookup_collect(key(1)).unwrap().len(), 2);
        assert!(cf.lookup_collect(key(2)).is_none());
    }

    #[test]
    fn agrees_with_unsharded_filter() {
        let mut plain = CuckooFilter::new(CuckooConfig::default());
        let sharded = ShardedCuckooFilter::new(CuckooConfig::default(), 8);
        for i in 0..3000 {
            let a = addrs((i % 5) as u32);
            assert_eq!(plain.insert(key(i), &a), sharded.insert(key(i), &a));
        }
        // Neither design may produce a false negative; address lists may
        // differ only at the paper's near-zero fingerprint-shadowing
        // rate (§4.5.1), which is layout- and therefore design-dependent.
        let mut mismatches = 0usize;
        for i in 0..3000 {
            let want = plain.lookup(key(i)).map(|h| plain.addresses(h));
            let got = sharded.lookup_collect(key(i));
            assert!(want.is_some(), "plain false negative for {i}");
            assert!(got.is_some(), "sharded false negative for {i}");
            if got != want {
                mismatches += 1;
            }
        }
        assert!(mismatches <= 10, "shadow rate too high: {mismatches}/3000");
    }

    #[test]
    fn temperature_bumps_through_read_path() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        cf.insert(key(1), &addrs(1));
        let mut out = Vec::new();
        for _ in 0..5 {
            out.clear();
            assert!(cf.lookup_into(key(1), &mut out));
        }
        assert_eq!(cf.temperature(key(1)), Some(5));
        cf.maintain(); // must not deadlock or lose the entry
        assert!(cf.contains_exact(key(1)));
    }

    #[test]
    fn stats_aggregate_across_shards() {
        let cf = ShardedCuckooFilter::new(CuckooConfig::default(), 4);
        for i in 0..100 {
            cf.insert(key(i), &addrs(1));
        }
        let mut out = Vec::new();
        for i in 0..100 {
            out.clear();
            cf.lookup_into(key(i), &mut out);
        }
        let s = cf.stats();
        assert_eq!(s.inserts, 100);
        assert_eq!(s.lookups, 100);
        assert!(s.slots_probed >= 100);
        assert!(cf.memory_bytes() > 0);
    }

    #[test]
    fn expansion_inside_a_shard_preserves_entries() {
        // total capacity 8 buckets over 4 shards -> 2 buckets/shard;
        // thousands of inserts force many per-shard expansions.
        let cf = ShardedCuckooFilter::new(
            CuckooConfig { initial_buckets: 8, ..CuckooConfig::default() },
            4,
        );
        for i in 0..5000 {
            assert!(cf.insert(key(i), &addrs(1)), "insert {i}");
        }
        assert!(cf.stats().expansions >= 4, "each shard should have grown");
        for i in 0..5000 {
            assert!(cf.lookup_collect(key(i)).is_some(), "lost {i}");
        }
    }
}
