//! Versioned, checksummed binary snapshot of the dynamic filter state.
//!
//! A snapshot captures exactly what a restart cannot rebuild from the
//! forest: the filter's live entry set — `(key, temperature, address
//! list)` per entity — plus the `partition_epoch` the backend was
//! serving when the snapshot was cut. Layout (all integers
//! little-endian):
//!
//! ```text
//! magic    8 B   "CFTSNAP\x01"
//! body:
//!   version          u32  (= 1)
//!   partition_epoch  u64
//!   entry_count      u64
//!   entries          entry_count ×
//!     key         u64
//!     temperature u32
//!     addr_count  u32
//!     addresses   addr_count × (tree u32, node u32)
//! crc      4 B   CRC-32 of the body
//! ```
//!
//! The trailing CRC covers the whole body, so a flipped bit anywhere —
//! header, counts, payload — fails verification before a single entry
//! is parsed; a corrupt snapshot is **refused loudly**, never loaded
//! partially. Writes are atomic: the bytes go to a sibling `.tmp` file
//! which is fsynced, renamed over the target, and the directory
//! fsynced — a crash mid-write leaves either the old snapshot or the
//! new one, never a torn hybrid (the `.tmp` leftover is ignored and
//! overwritten by the next write).

use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::Path;

use super::crc::crc32;
use crate::forest::EntityAddress;

/// File magic: identifies the format and its major revision.
pub const MAGIC: &[u8; 8] = b"CFTSNAP\x01";

/// Body format version (bumped on incompatible layout changes).
pub const VERSION: u32 = 1;

/// One decoded snapshot: the recorded membership epoch plus every live
/// filter entry as `(key, temperature, addresses)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Snapshot {
    /// The `partition_epoch` the backend served when the snapshot was
    /// cut — what the router's `EpochGate` checks at re-admission.
    pub partition_epoch: u64,
    /// Live entries: `(entity key, temperature, address list)`.
    pub entries: Vec<(u64, u32, Vec<EntityAddress>)>,
}

impl Snapshot {
    /// Serialize to the on-disk byte layout (magic + body + CRC).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(24 + self.entries.len() * 24);
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.extend_from_slice(&self.partition_epoch.to_le_bytes());
        body.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for (key, temp, addrs) in &self.entries {
            body.extend_from_slice(&key.to_le_bytes());
            body.extend_from_slice(&temp.to_le_bytes());
            body.extend_from_slice(&(addrs.len() as u32).to_le_bytes());
            for a in addrs {
                body.extend_from_slice(&a.tree.to_le_bytes());
                body.extend_from_slice(&a.node.to_le_bytes());
            }
        }
        let mut out = Vec::with_capacity(MAGIC.len() + body.len() + 4);
        out.extend_from_slice(MAGIC);
        let crc = crc32(&body);
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Decode from on-disk bytes, verifying magic, version and CRC.
    /// Every failure is a loud [`io::ErrorKind::InvalidData`] — a
    /// corrupt snapshot must never be loaded in part.
    pub fn from_bytes(bytes: &[u8]) -> io::Result<Snapshot> {
        let corrupt = |what: &str| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("corrupt snapshot: {what}"),
            )
        };
        if bytes.len() < MAGIC.len() + 4 {
            return Err(corrupt("shorter than magic + checksum"));
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err(corrupt("bad magic (not a CFT snapshot?)"));
        }
        let body = &bytes[MAGIC.len()..bytes.len() - 4];
        let stored = u32::from_le_bytes(
            bytes[bytes.len() - 4..].try_into().expect("4-byte tail"),
        );
        if crc32(body) != stored {
            return Err(corrupt("body checksum mismatch"));
        }
        let mut r = Reader { buf: body, pos: 0 };
        let version = r.u32().map_err(|_| corrupt("truncated header"))?;
        if version != VERSION {
            return Err(corrupt(&format!(
                "unsupported version {version} (this build reads {VERSION})"
            )));
        }
        let partition_epoch =
            r.u64().map_err(|_| corrupt("truncated header"))?;
        let count = r.u64().map_err(|_| corrupt("truncated header"))?;
        let mut entries = Vec::with_capacity(count.min(1 << 20) as usize);
        for _ in 0..count {
            let key = r.u64().map_err(|_| corrupt("truncated entry"))?;
            let temp = r.u32().map_err(|_| corrupt("truncated entry"))?;
            let naddrs = r.u32().map_err(|_| corrupt("truncated entry"))?;
            let mut addrs = Vec::with_capacity(naddrs.min(1 << 20) as usize);
            for _ in 0..naddrs {
                let tree =
                    r.u32().map_err(|_| corrupt("truncated address"))?;
                let node =
                    r.u32().map_err(|_| corrupt("truncated address"))?;
                addrs.push(EntityAddress::new(tree, node));
            }
            entries.push((key, temp, addrs));
        }
        if r.pos != body.len() {
            return Err(corrupt("trailing bytes after last entry"));
        }
        Ok(Snapshot { partition_epoch, entries })
    }
}

/// Bounds-checked little-endian cursor over the snapshot body.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8], ()> {
        if self.pos + n > self.buf.len() {
            return Err(());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, ()> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ()> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Atomically replace the snapshot at `path`: write a sibling `.tmp`
/// file, fsync it, rename it over `path`, then fsync the directory so
/// the rename itself is durable. A crash at any point leaves `path`
/// holding either the previous complete snapshot or the new one.
pub fn write_atomic(path: &Path, snapshot: &Snapshot) -> io::Result<()> {
    let tmp = tmp_path(path);
    {
        let mut f = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp)?;
        f.write_all(&snapshot.to_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        // Directory fsync makes the rename durable on Linux; platforms
        // where opening a directory fails simply skip it (best effort).
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Load and verify the snapshot at `path`.
pub fn load(path: &Path) -> io::Result<Snapshot> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    Snapshot::from_bytes(&bytes)
}

/// The sibling temp-file path a [`write_atomic`] stages into
/// (`<file>.tmp` in the same directory, so the rename never crosses a
/// filesystem).
pub fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            partition_epoch: 7,
            entries: vec![
                (
                    0xDEAD_BEEF,
                    42,
                    vec![EntityAddress::new(1, 2), EntityAddress::new(3, 4)],
                ),
                (0x1234, 0, vec![]),
                (u64::MAX, u32::MAX, vec![EntityAddress::new(0, 0)]),
            ],
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("cft-snap-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrips_bytes() {
        let s = sample();
        assert_eq!(Snapshot::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn roundtrips_through_disk_atomically() {
        let dir = tmp_dir("disk");
        let path = dir.join("snapshot.cft");
        let s = sample();
        write_atomic(&path, &s).unwrap();
        assert_eq!(load(&path).unwrap(), s);
        assert!(!tmp_path(&path).exists(), "tmp staging file renamed away");
        // overwrite is atomic too: the new content fully replaces
        let s2 = Snapshot { partition_epoch: 8, entries: vec![] };
        write_atomic(&path, &s2).unwrap();
        assert_eq!(load(&path).unwrap(), s2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let s = Snapshot { partition_epoch: 0, entries: vec![] };
        assert_eq!(Snapshot::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn bad_magic_refused() {
        let mut b = sample().to_bytes();
        b[0] ^= 0xFF;
        let err = Snapshot::from_bytes(&b).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn truncated_file_refused() {
        let b = sample().to_bytes();
        for cut in [0, 5, MAGIC.len(), b.len() - 5, b.len() - 1] {
            assert!(
                Snapshot::from_bytes(&b[..cut]).is_err(),
                "cut at {cut} accepted"
            );
        }
    }

    #[test]
    fn any_flipped_body_bit_is_detected() {
        let b = sample().to_bytes();
        // flip one bit in every body/crc byte; all must be refused
        for i in MAGIC.len()..b.len() {
            let mut c = b.clone();
            c[i] ^= 0x10;
            assert!(
                Snapshot::from_bytes(&c).is_err(),
                "flip at byte {i} loaded silently"
            );
        }
    }

    #[test]
    fn future_version_refused_loudly() {
        let s = Snapshot { partition_epoch: 1, entries: vec![] };
        let mut b = s.to_bytes();
        // bump the version field, then re-stamp the CRC so only the
        // version check can object
        b[MAGIC.len()] = 99;
        let body_end = b.len() - 4;
        let crc = crc32(&b[MAGIC.len()..body_end]);
        b[body_end..].copy_from_slice(&crc.to_le_bytes());
        let err = Snapshot::from_bytes(&b).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }
}
