//! Router-level metrics, in the same shape as `coordinator/metrics.rs`:
//! a cheap mutex-guarded sink, cloneable across threads, snapshotted on
//! demand. Per-backend latency uses the shared [`LatencyHistogram`].

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Snapshot of one backend's counters at an instant.
#[derive(Clone, Debug)]
pub struct BackendMetricsSnapshot {
    pub addr: String,
    /// Health at snapshot time (from the backend's [`HealthState`]).
    ///
    /// [`HealthState`]: crate::router::health::HealthState
    pub healthy: bool,
    pub requests: u64,
    pub failures: u64,
    pub latency_mean_s: f64,
    pub latency_p99_s: f64,
}

/// Snapshot of the router's counters at an instant.
#[derive(Clone, Debug)]
pub struct RouterMetricsSnapshot {
    /// Queries answered (one per `Router::query`, merged or not).
    pub requests: u64,
    /// Queries that could not produce an `ok` reply at all.
    pub failures: u64,
    /// Queries fanned out to more than one backend.
    pub fanouts: u64,
    /// Sub-requests served by a backend other than the key's owner.
    pub failovers: u64,
    /// Replicated-mode sub-requests served by a non-owner replica
    /// *without* any candidate failing first — the least-loaded load
    /// balancer's choice, not a rescue.
    pub replica_hits: u64,
    /// Merged replies missing at least one portion.
    pub degraded: u64,
    /// Broadcast writes (`\x01insert`/`\x01delete` fan-outs).
    pub write_fanouts: u64,
    /// Broadcast writes that missed their ack quorum.
    pub quorum_fails: u64,
    pub backends: Vec<BackendMetricsSnapshot>,
}

impl RouterMetricsSnapshot {
    /// Queries per second over an elapsed window.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed.as_secs_f64()
        }
    }

    /// JSON form (the router front door's `\x01stats` payload).
    pub fn to_json(&self) -> Json {
        let backends = self
            .backends
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("addr", Json::Str(b.addr.clone())),
                    ("healthy", Json::Bool(b.healthy)),
                    ("requests", Json::Num(b.requests as f64)),
                    ("failures", Json::Num(b.failures as f64)),
                    ("latency_mean_s", Json::Num(b.latency_mean_s)),
                    ("latency_p99_s", Json::Num(b.latency_p99_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("fanouts", Json::Num(self.fanouts as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("replica_hits", Json::Num(self.replica_hits as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("write_fanouts", Json::Num(self.write_fanouts as f64)),
            ("quorum_fails", Json::Num(self.quorum_fails as f64)),
            ("backends", Json::Arr(backends)),
        ])
    }
}

#[derive(Debug, Default)]
struct BackendInner {
    requests: u64,
    failures: u64,
    latency: LatencyHistogram,
}

#[derive(Debug)]
struct Inner {
    requests: u64,
    failures: u64,
    fanouts: u64,
    failovers: u64,
    replica_hits: u64,
    degraded: u64,
    write_fanouts: u64,
    quorum_fails: u64,
    backends: Vec<BackendInner>,
}

/// Thread-shared router metrics sink.
#[derive(Clone, Debug)]
pub struct RouterMetrics {
    inner: Arc<Mutex<Inner>>,
}

impl RouterMetrics {
    /// New sink for `nbackends` backends.
    pub fn new(nbackends: usize) -> Self {
        RouterMetrics {
            inner: Arc::new(Mutex::new(Inner {
                requests: 0,
                failures: 0,
                fanouts: 0,
                failovers: 0,
                replica_hits: 0,
                degraded: 0,
                write_fanouts: 0,
                quorum_fails: 0,
                backends: (0..nbackends)
                    .map(|_| BackendInner::default())
                    .collect(),
            })),
        }
    }

    /// Record one completed `Router::query` (ok or not).
    pub fn record_query(&self, ok: bool) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        if !ok {
            m.failures += 1;
        }
    }

    /// Record a multi-backend fanned-out query.
    pub fn record_fanout(&self) {
        self.inner.lock().unwrap().fanouts += 1;
    }

    /// Record a sub-request served off-owner.
    pub fn record_failover(&self) {
        self.inner.lock().unwrap().failovers += 1;
    }

    /// Record a sub-request served by a non-owner replica by load
    /// choice (replicated mode, nothing failed first).
    pub fn record_replica_hit(&self) {
        self.inner.lock().unwrap().replica_hits += 1;
    }

    /// Record a merged reply with a missing portion.
    pub fn record_degraded(&self) {
        self.inner.lock().unwrap().degraded += 1;
    }

    /// Record one broadcast write fan-out.
    pub fn record_write_fanout(&self) {
        self.inner.lock().unwrap().write_fanouts += 1;
    }

    /// Record a broadcast write that missed its ack quorum.
    pub fn record_quorum_fail(&self) {
        self.inner.lock().unwrap().quorum_fails += 1;
    }

    /// Record one backend round trip.
    pub fn record_backend(&self, idx: usize, ok: bool, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        let b = &mut m.backends[idx];
        b.requests += 1;
        if !ok {
            b.failures += 1;
        }
        b.latency.record(latency.as_secs_f64());
    }

    /// Snapshot against backend identities: `info[i]` is backend `i`'s
    /// `(addr, healthy-now)` — health lives with the backends, not in
    /// this sink, so the caller (the router) joins the two.
    pub fn snapshot(&self, info: &[(String, bool)]) -> RouterMetricsSnapshot {
        let m = self.inner.lock().unwrap();
        assert_eq!(m.backends.len(), info.len(), "backend count mismatch");
        RouterMetricsSnapshot {
            requests: m.requests,
            failures: m.failures,
            fanouts: m.fanouts,
            failovers: m.failovers,
            replica_hits: m.replica_hits,
            degraded: m.degraded,
            write_fanouts: m.write_fanouts,
            quorum_fails: m.quorum_fails,
            backends: m
                .backends
                .iter()
                .zip(info)
                .map(|(b, (addr, healthy))| BackendMetricsSnapshot {
                    addr: addr.clone(),
                    healthy: *healthy,
                    requests: b.requests,
                    failures: b.failures,
                    latency_mean_s: b.latency.mean(),
                    latency_p99_s: b.latency.quantile(0.99),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots_per_backend() {
        let m = RouterMetrics::new(2);
        m.record_query(true);
        m.record_query(false);
        m.record_fanout();
        m.record_failover();
        m.record_replica_hit();
        m.record_replica_hit();
        m.record_degraded();
        m.record_write_fanout();
        m.record_quorum_fail();
        m.record_backend(0, true, Duration::from_millis(2));
        m.record_backend(1, false, Duration::from_millis(4));
        let info = vec![("a:1".to_string(), true), ("b:2".to_string(), false)];
        let s = m.snapshot(&info);
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 1);
        assert_eq!(s.fanouts, 1);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.replica_hits, 2);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.write_fanouts, 1);
        assert_eq!(s.quorum_fails, 1);
        assert_eq!(s.backends[0].requests, 1);
        assert_eq!(s.backends[0].failures, 0);
        assert!(s.backends[0].healthy);
        assert_eq!(s.backends[1].failures, 1);
        assert!(!s.backends[1].healthy);
        assert!(s.backends[1].latency_mean_s > 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let m = RouterMetrics::new(1);
        m.record_query(true);
        m.record_backend(0, true, Duration::from_micros(500));
        let s = m.snapshot(&[("x:1".to_string(), true)]);
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        for field in ["replica_hits", "write_fanouts", "quorum_fails"] {
            assert_eq!(
                back.get(field).and_then(Json::as_f64),
                Some(0.0),
                "{field} missing from the stats payload"
            );
        }
        let backends = back.get("backends").unwrap().as_arr().unwrap();
        assert_eq!(backends[0].get("addr").and_then(Json::as_str), Some("x:1"));
        assert_eq!(backends[0].get("healthy"), Some(&Json::Bool(true)));
    }

    #[test]
    fn throughput_math() {
        let m = RouterMetrics::new(0);
        for _ in 0..50 {
            m.record_query(true);
        }
        let s = m.snapshot(&[]);
        assert!((s.throughput(Duration::from_secs(5)) - 10.0).abs() < 1e-9);
    }
}
