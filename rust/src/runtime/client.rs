//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client. This is the ONLY place Python-authored compute
//! enters the Rust hot path; Python itself never runs at serve time.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! DESIGN.md): jax >= 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Compiled only with the `xla` feature (a vendored `xla` crate /
//! xla_extension build): the default dependency-free build substitutes
//! a stub whose `load` always errs, so callers take their documented
//! `NativeEngine` fallback path instead of failing to link.

use std::path::Path;

use crate::error::{CftError, Result};
use crate::runtime::artifact::Manifest;

/// Compiled artifacts + the PJRT client that runs them.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
    embed_exe: xla::PjRtLoadedExecutable,
    score_exe: xla::PjRtLoadedExecutable,
    rank_exe: xla::PjRtLoadedExecutable,
    manifest: Manifest,
}

#[cfg(feature = "xla")]
impl Runtime {
    /// Load every artifact from `dir` and compile it on the CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.hlo_path(name);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        Ok(Runtime {
            embed_exe: compile("embed")?,
            score_exe: compile("score")?,
            rank_exe: compile("rank")?,
            client,
            manifest,
        })
    }

    /// The artifact manifest (shapes).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Embed a padded token batch.
    ///
    /// `tokens` is row-major `[batch, max_tokens]`; returns row-major
    /// `[batch, embed_dim]` L2-normalized embeddings.
    pub fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let expect = m.batch * m.max_tokens;
        if tokens.len() != expect {
            return Err(CftError::Runtime(format!(
                "embed input len {} != {}x{}",
                tokens.len(),
                m.batch,
                m.max_tokens
            )));
        }
        let lit = xla::Literal::vec1(tokens)
            .reshape(&[m.batch as i64, m.max_tokens as i64])?;
        self.run1(&self.embed_exe, &[lit], m.batch * m.embed_dim)
    }

    /// Score a query batch against one corpus shard.
    ///
    /// `q` is `[batch, embed_dim]`, `docs` is `[shard_docs, embed_dim]`;
    /// returns `[batch, shard_docs]` similarity scores.
    pub fn score(&self, q: &[f32], docs: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        if q.len() != m.batch * m.embed_dim {
            return Err(CftError::Runtime(format!(
                "score q len {} != {}x{}",
                q.len(),
                m.batch,
                m.embed_dim
            )));
        }
        if docs.len() != m.shard_docs * m.embed_dim {
            return Err(CftError::Runtime(format!(
                "score docs len {} != {}x{}",
                docs.len(),
                m.shard_docs,
                m.embed_dim
            )));
        }
        let ql = xla::Literal::vec1(q)
            .reshape(&[m.batch as i64, m.embed_dim as i64])?;
        let dl = xla::Literal::vec1(docs)
            .reshape(&[m.shard_docs as i64, m.embed_dim as i64])?;
        self.run1(&self.score_exe, &[ql, dl], m.batch * m.shard_docs)
    }

    /// Attention-rank facts for each request in a batch.
    ///
    /// `q` is `[batch, embed_dim]`, `facts` is
    /// `[batch, max_facts, embed_dim]` zero-padded, `lens[b]` counts the
    /// valid facts; returns `[batch, max_facts]` attention weights.
    pub fn rank(&self, q: &[f32], facts: &[f32], lens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        if q.len() != m.batch * m.embed_dim
            || facts.len() != m.batch * m.max_facts * m.embed_dim
            || lens.len() != m.batch
        {
            return Err(CftError::Runtime("rank input shape mismatch".into()));
        }
        let ql = xla::Literal::vec1(q)
            .reshape(&[m.batch as i64, m.embed_dim as i64])?;
        let fl = xla::Literal::vec1(facts).reshape(&[
            m.batch as i64,
            m.max_facts as i64,
            m.embed_dim as i64,
        ])?;
        let ll = xla::Literal::vec1(lens).reshape(&[m.batch as i64])?;
        self.run1(&self.rank_exe, &[ql, fl, ll], m.batch * m.max_facts)
    }

    /// Execute a 1-output-tuple executable and pull the f32 result.
    fn run1(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
        expect_len: usize,
    ) -> Result<Vec<f32>> {
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != expect_len {
            return Err(CftError::Runtime(format!(
                "output len {} != expected {expect_len}",
                values.len()
            )));
        }
        Ok(values)
    }
}

// SAFETY: `Runtime` owns its PJRT client and loaded executables
// exclusively — the raw handles inside the `xla` wrapper types are
// created in `Runtime::load`, never aliased outside the struct, and
// PJRT's C API permits a client and its executables to be *used from
// one thread at a time* (which is what `Send`-without-`Sync` encodes:
// the wrapper may move to another thread, but `&Runtime` never crosses
// threads concurrently). The coordinator upholds the single-thread-at-
// a-time discipline by driving the runtime from one dedicated executor
// thread; nothing hands out `&Runtime` across threads (no `Sync` impl
// is provided, so the compiler enforces that part). If the `xla`
// wrapper ever gains thread-affine state (e.g. a thread-local stream),
// this impl must be revisited.
#[cfg(feature = "xla")]
unsafe impl Send for Runtime {}

// ---------------------------------------------------------------------
// Dependency-free stub (default build)
// ---------------------------------------------------------------------

/// Stub runtime for builds without the `xla` feature. [`Runtime::load`]
/// still validates the artifact directory (so missing-artifact errors
/// read the same), then reports that PJRT execution is unavailable;
/// every caller already falls back to `NativeEngine` on that error.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    /// Always errs (after manifest validation): PJRT is not compiled in.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = Manifest::load(&dir)?;
        Err(CftError::Runtime(
            "PJRT execution not compiled in (build with the `xla` feature \
             and a vendored xla crate); falling back to the native engine"
                .into(),
        ))
    }

    /// The artifact manifest (shapes).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Embed a padded token batch (unreachable: see [`Runtime::load`]).
    pub fn embed(&self, _tokens: &[i32]) -> Result<Vec<f32>> {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Score a query batch (unreachable: see [`Runtime::load`]).
    pub fn score(&self, _q: &[f32], _docs: &[f32]) -> Result<Vec<f32>> {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Rank facts (unreachable: see [`Runtime::load`]).
    pub fn rank(&self, _q: &[f32], _facts: &[f32], _lens: &[i32]) -> Result<Vec<f32>> {
        unreachable!("stub Runtime cannot be constructed")
    }
}
