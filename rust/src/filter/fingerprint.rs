//! Fingerprints and bucket indexing for the Cuckoo Filter (paper §3.2).
//!
//! An entity key (64-bit hash of its name) is reduced to a short
//! fingerprint `f(x)` (12 bits by default, paper §1) and a primary bucket
//! `i1 = h(x)`. The alternate bucket is `i2 = i1 XOR h(f(x))` — the
//! partial-key cuckoo scheme of Fan et al. 2014, chosen so that either
//! bucket index plus the fingerprint recovers the other (`alt(alt(i)) ==
//! i`), which is what makes eviction possible without the original key.

use crate::util::rng::fnv1a;

/// Entity key: stable 64-bit hash of the (normalized) entity name.
pub fn entity_key(name: &str) -> u64 {
    fnv1a(name.as_bytes())
}

/// Secondary mix so fingerprint bits are independent of index bits.
#[inline]
fn mix(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    h = h.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    h ^ (h >> 33)
}

/// Fingerprint of a key: `bits` wide, never zero (zero marks empty slots).
#[inline]
pub fn fingerprint(key: u64, bits: u32) -> u16 {
    debug_assert!((1..=16).contains(&bits));
    let mask = ((1u32 << bits) - 1) as u64;
    let fp = (mix(key) & mask) as u16;
    if fp == 0 { 1 } else { fp }
}

/// Primary bucket index `i1 = h(x)` for a table of `nbuckets` (power of 2).
#[inline]
pub fn primary_index(key: u64, nbuckets: usize) -> usize {
    debug_assert!(nbuckets.is_power_of_two());
    (key as usize) & (nbuckets - 1)
}

/// Shard index for a key in a table partitioned `nshards` ways (power of
/// two). Uses the *high* bits of the secondary mix so it is independent
/// of both the in-shard bucket index (low key bits) and the fingerprint
/// (low mix bits) — a shard sees a uniform slice of the key space.
#[inline]
pub fn shard_index(key: u64, nshards: usize) -> usize {
    debug_assert!(nshards.is_power_of_two());
    ((mix(key) >> 48) as usize) & (nshards - 1)
}

/// Rendezvous (highest-random-weight) score of `key` on the backend
/// identified by `seed` — the cross-process extension of the same hash
/// family: the router's `ShardRing` ranks backends by this score exactly
/// as [`shard_index`] picks an in-process shard. Mixing `key` with an
/// already-mixed `seed` keeps the score independent of the bits consumed
/// by [`primary_index`] (low key bits), [`fingerprint`] (low mix bits)
/// and [`shard_index`] (high mix bits), so routing a key to a backend
/// and then sharding it inside that backend never correlate: both
/// levels of sharding compose without load skew.
#[inline]
pub fn rendezvous_score(key: u64, seed: u64) -> u64 {
    mix(key ^ mix(seed))
}

/// Alternate bucket index `i XOR h(f)` — involutive for fixed `nbuckets`.
#[inline]
pub fn alt_index(index: usize, fp: u16, nbuckets: usize) -> usize {
    debug_assert!(nbuckets.is_power_of_two());
    // hash the fingerprint so sparse fp values still spread across buckets
    let h = mix(fp as u64) as usize;
    (index ^ h) & (nbuckets - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_nonzero_and_bounded() {
        for bits in [8u32, 12, 16] {
            for k in 0..5000u64 {
                let fp = fingerprint(k.wrapping_mul(0x9E3779B97F4A7C15), bits);
                assert!(fp > 0);
                assert!((fp as u32) < (1 << bits));
            }
        }
    }

    #[test]
    fn alt_index_is_involution() {
        let n = 1024;
        for k in 0..2000u64 {
            let key = fnv1a(&k.to_le_bytes());
            let fp = fingerprint(key, 12);
            let i1 = primary_index(key, n);
            let i2 = alt_index(i1, fp, n);
            assert_eq!(alt_index(i2, fp, n), i1, "involution broken");
        }
    }

    #[test]
    fn fingerprints_spread() {
        // 12-bit fingerprints over 4096 values: expect good coverage
        let mut seen = vec![false; 1 << 12];
        for k in 0..20_000u64 {
            seen[fingerprint(fnv1a(&k.to_le_bytes()), 12) as usize] = true;
        }
        let covered = seen.iter().filter(|&&s| s).count();
        assert!(covered > 3500, "only {covered} fingerprints seen");
    }

    #[test]
    fn indexes_spread_over_buckets() {
        let n = 256;
        let mut counts = vec![0usize; n];
        for k in 0..10_000u64 {
            counts[primary_index(fnv1a(&k.to_le_bytes()), n)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 10 && max < 100, "skewed: min={min} max={max}");
    }

    #[test]
    fn shard_index_spreads_and_bounds() {
        let n = 8;
        let mut counts = vec![0usize; n];
        for k in 0..8_000u64 {
            let s = shard_index(fnv1a(&k.to_le_bytes()), n);
            assert!(s < n);
            counts[s] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 700 && max < 1300, "skewed: min={min} max={max}");
    }

    #[test]
    fn rendezvous_scores_spread_and_decorrelate() {
        // Two backends: roughly half the keys prefer each, and the
        // winner is independent of the key's in-process shard.
        let (seed_a, seed_b) = (fnv1a(b"backend-a"), fnv1a(b"backend-b"));
        let mut a_wins = 0usize;
        let mut joint = [[0usize; 2]; 2];
        let n = 8_000u64;
        for k in 0..n {
            let key = fnv1a(&k.to_le_bytes());
            let a = rendezvous_score(key, seed_a) > rendezvous_score(key, seed_b);
            if a {
                a_wins += 1;
            }
            joint[a as usize][shard_index(key, 2)] += 1;
        }
        assert!(
            a_wins > 3_500 && a_wins < 4_500,
            "skewed backend choice: {a_wins}/{n}"
        );
        // every (backend winner, shard) cell near n/4: no correlation
        for row in joint {
            for cell in row {
                assert!(
                    cell > 1_700 && cell < 2_300,
                    "backend/shard correlated: {joint:?}"
                );
            }
        }
    }

    #[test]
    fn entity_key_stable() {
        assert_eq!(entity_key("cardiology"), entity_key("cardiology"));
        assert_ne!(entity_key("cardiology"), entity_key("oncology"));
    }
}
