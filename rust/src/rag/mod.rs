//! The CFT-RAG pipeline (Figure 1) and its configuration.

pub mod config;
pub mod pipeline;

pub use config::{Algorithm, RagConfig};
pub use pipeline::{
    make_concurrent_retriever, make_retriever, RagPipeline, RagResponse,
};
