//! Bounded condition polling — the one sanctioned way to wait for
//! cross-thread state in tests and maintenance paths.
//!
//! A bare `thread::sleep(<guessed duration>)` before asserting on
//! another thread's progress is a flake generator: too short and slow
//! CI fails, too long and every run pays the worst case. Polling a
//! condition against a generous deadline is deterministic in outcome
//! (the condition either holds within the budget or it genuinely never
//! will) and costs only as long as the condition actually takes.

use std::time::{Duration, Instant};

/// Interval between condition checks. Short enough that a wait costs
/// barely more than the condition itself takes to become true.
const POLL: Duration = Duration::from_millis(2);

/// Poll `cond` until it returns `true` or `timeout` elapses. Returns
/// whether the condition held — with one final check at the deadline,
/// so a condition that becomes true exactly as time runs out still
/// counts.
pub fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return cond();
        }
        std::thread::sleep(POLL);
    }
}

/// [`wait_until`] that panics with `what` on timeout — the test-side
/// form: `require("prober sees the load", SECS_10, || observed() >= 3)`.
pub fn require(what: &str, timeout: Duration, cond: impl FnMut() -> bool) {
    assert!(wait_until(timeout, cond), "timed out waiting: {what}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    #[test]
    fn true_condition_returns_immediately() {
        let t = Instant::now();
        assert!(wait_until(Duration::from_secs(10), || true));
        assert!(t.elapsed() < Duration::from_secs(1), "no pointless wait");
    }

    #[test]
    fn false_condition_times_out() {
        assert!(!wait_until(Duration::from_millis(10), || false));
    }

    #[test]
    fn sees_condition_flipped_by_another_thread() {
        let flag = Arc::new(AtomicBool::new(false));
        let setter = {
            let flag = Arc::clone(&flag);
            std::thread::spawn(move || flag.store(true, Ordering::Release))
        };
        assert!(wait_until(Duration::from_secs(10), || {
            flag.load(Ordering::Acquire)
        }));
        setter.join().unwrap();
    }
}
