//! Shard-router integration over REAL in-process TCP backends: each
//! backend is a full coordinator (batcher, workers, maintainer) behind
//! `coordinator/tcp.rs`, started with `serve_with_shutdown` /
//! `serve_listener` so tests can kill and restart backends without
//! leaking listeners — the graceful-shutdown satellite of PR 3 and the
//! replicated/partitioned serving of ISSUE 4 exercised end to end.

use std::net::TcpListener;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use cft_rag::coordinator::tcp::{serve_listener, ServeHandle};
use cft_rag::coordinator::{Coordinator, CoordinatorConfig};
use cft_rag::data::corpus::corpus_from_texts;
use cft_rag::data::hospital::{HospitalConfig, HospitalDataset};
use cft_rag::filter::fingerprint::entity_key;
use cft_rag::rag::config::{KeyPartition, RagConfig, RouterConfig};
use cft_rag::router::Router;
use cft_rag::runtime::engine::{Engine, NativeEngine};
use cft_rag::util::json::Json;

/// One in-process backend: a coordinator behind a real TCP listener.
struct TestBackend {
    coordinator: Arc<Coordinator>,
    handle: Option<ServeHandle>,
    addr: String,
}

impl TestBackend {
    fn start(ds: &HospitalDataset, addr: &str) -> TestBackend {
        let listener = TcpListener::bind(addr).expect("bind backend");
        Self::start_on(ds, listener, RagConfig::default())
    }

    /// Start on an already-bound listener with an explicit `RagConfig`
    /// — the partitioned-fleet path (every address must exist before
    /// any index is built).
    fn start_on(
        ds: &HospitalDataset,
        listener: TcpListener,
        cfg: RagConfig,
    ) -> TestBackend {
        let forest = Arc::new(ds.build_forest());
        let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new());
        let coordinator = Arc::new(
            Coordinator::start(
                forest,
                corpus_from_texts(&ds.documents()),
                engine,
                cfg,
                CoordinatorConfig { workers: 2, ..Default::default() },
            )
            .expect("backend coordinator"),
        );
        let handle = serve_listener(coordinator.clone(), listener)
            .expect("backend listener");
        let addr = handle.addr().to_string();
        TestBackend { coordinator, handle: Some(handle), addr }
    }

    /// Hard stop: listener down, coordinator drained and joined.
    fn kill(&mut self) {
        if let Some(h) = self.handle.take() {
            h.shutdown();
        }
        self.coordinator.stop();
    }
}

impl Drop for TestBackend {
    fn drop(&mut self) {
        self.kill();
    }
}

fn dataset(trees: usize) -> HospitalDataset {
    HospitalDataset::generate(HospitalConfig {
        trees,
        ..HospitalConfig::default()
    })
}

fn entity_names(ds: &HospitalDataset) -> Vec<String> {
    ds.build_forest()
        .interner()
        .iter()
        .map(|(_, n)| n.to_string())
        .collect()
}

fn cluster(
    ds: &HospitalDataset,
    n: usize,
    cfg: &RouterConfig,
) -> (Vec<TestBackend>, Arc<Router>) {
    let backends: Vec<TestBackend> =
        (0..n).map(|_| TestBackend::start(ds, "127.0.0.1:0")).collect();
    let cfg = RouterConfig {
        backends: backends.iter().map(|b| b.addr.clone()).collect(),
        ..cfg.clone()
    };
    let names = entity_names(ds);
    let router = Arc::new(
        Router::connect(names.iter().map(String::as_str), &cfg)
            .expect("router"),
    );
    (backends, router)
}

/// A **key-partitioned** fleet with R-way replication: every backend
/// indexes only the keys whose replica set contains it (so a backend
/// serving another backend's key would return nothing — the router must
/// stay within replica sets), and the router runs in replicated mode.
fn partitioned_cluster(
    ds: &HospitalDataset,
    n: usize,
    r: usize,
    cfg: &RouterConfig,
) -> (Vec<TestBackend>, Arc<Router>) {
    // bind all listeners first: the partition hashes the address list
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
        .collect();
    let addrs: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let backends: Vec<TestBackend> = listeners
        .into_iter()
        .enumerate()
        .map(|(i, listener)| {
            let cfg = RagConfig {
                replication_factor: r,
                key_partition: Some(
                    KeyPartition::new(addrs.clone(), i, r).expect("partition"),
                ),
                ..RagConfig::default()
            };
            TestBackend::start_on(ds, listener, cfg)
        })
        .collect();
    let cfg = RouterConfig {
        backends: addrs,
        replication_factor: r,
        ..cfg.clone()
    };
    let names = entity_names(ds);
    let router = Arc::new(
        Router::connect(names.iter().map(String::as_str), &cfg)
            .expect("router"),
    );
    (backends, router)
}

/// Deterministic-traffic config: no background prober.
fn quiet_cfg() -> RouterConfig {
    RouterConfig {
        probe_interval: Duration::ZERO,
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_secs(10),
        ..RouterConfig::default()
    }
}

fn is_ok(reply: &Json) -> bool {
    reply.get("ok") == Some(&Json::Bool(true))
}

#[test]
fn single_entity_queries_route_deterministically() {
    let ds = dataset(4);
    let (_backends, router) = cluster(&ds, 4, &quiet_cfg());
    for _ in 0..3 {
        let reply = router.query("what is the parent unit of cardiology");
        assert!(is_ok(&reply), "{reply}");
        assert_eq!(reply.get("backends").and_then(Json::as_f64), Some(1.0));
        assert!(reply
            .get("entities")
            .and_then(Json::as_arr)
            .is_some_and(|e| !e.is_empty()));
    }
    // all three identical queries landed on the one owning backend
    let snap = router.snapshot();
    let loads: Vec<u64> = snap.backends.iter().map(|b| b.requests).collect();
    assert_eq!(loads.iter().sum::<u64>(), 3, "{loads:?}");
    assert_eq!(loads.iter().filter(|&&r| r > 0).count(), 1, "{loads:?}");
    let owner = router.ring().owner(entity_key("cardiology")).unwrap();
    assert!(loads[owner] == 3, "owner {owner} should serve all: {loads:?}");
}

#[test]
fn multi_owner_queries_scatter_and_merge() {
    let ds = dataset(6);
    let (_backends, router) = cluster(&ds, 4, &quiet_cfg());
    // pick entities until they span at least two owners (which exact
    // names spread where depends only on stable hashes, so walk the
    // vocabulary instead of hard-coding hash outcomes)
    let names = entity_names(&ds);
    let mut picked: Vec<&str> = Vec::new();
    let mut owners = std::collections::BTreeSet::new();
    for n in &names {
        picked.push(n);
        owners.insert(router.ring().owner(entity_key(n)).unwrap());
        if owners.len() >= 2 && picked.len() >= 3 {
            break;
        }
    }
    assert!(owners.len() >= 2, "vocabulary spans one owner only?");
    let query = format!("describe the hierarchy around {}", picked.join(" and "));
    let reply = router.query(&query);
    assert!(is_ok(&reply), "{reply}");
    assert_eq!(
        reply.get("backends").and_then(Json::as_f64),
        Some(owners.len() as f64),
        "one portion per owner: {reply}"
    );
    assert_eq!(reply.get("degraded"), Some(&Json::Bool(false)));
    let merged: Vec<&str> = reply
        .get("entities")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    for p in &picked {
        assert!(merged.contains(p), "{p} missing from merged {merged:?}");
    }
    assert!(router.snapshot().fanouts >= 1);
}

#[test]
fn killing_one_backend_mid_load_fails_zero_queries() {
    // The ISSUE-4 acceptance scenario: the backends are KEY-PARTITIONED
    // (each indexes only its owned ~R/N of the keys, so failing over to
    // a non-replica would silently lose facts) with R=2 replication.
    // Killing one backend mid-load must fail zero queries AND degrade
    // zero replies — every key still has a live replica.
    let ds = dataset(6);
    let (mut backends, router) =
        partitioned_cluster(&ds, 3, 2, &quiet_cfg());
    let names = entity_names(&ds);
    let queries: Vec<String> = names
        .iter()
        .take(24)
        .map(|n| format!("where does {n} sit in the organization"))
        .collect();

    const CLIENTS: usize = 4;
    const PHASE1: usize = 5;
    const PHASE2: usize = 20;
    let mid_load = Arc::new(Barrier::new(CLIENTS + 1));
    let failures = Mutex::new(Vec::<String>::new());

    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let router = router.clone();
            let mid_load = mid_load.clone();
            let queries = &queries;
            let failures = &failures;
            s.spawn(move || {
                let mut serve = |i: usize| {
                    let q = &queries[(c * 7 + i) % queries.len()];
                    let reply = router.query(q);
                    if !is_ok(&reply) {
                        failures.lock().unwrap().push(reply.to_string());
                    }
                };
                for i in 0..PHASE1 {
                    serve(i);
                }
                // all clients are mid-load when the kill happens; they
                // keep querying while backend 0 goes down
                mid_load.wait();
                for i in PHASE1..PHASE1 + PHASE2 {
                    serve(i);
                }
            });
        }
        mid_load.wait();
        backends[0].kill();
    });

    let failed = failures.into_inner().unwrap();
    assert!(
        failed.is_empty(),
        "{} queries failed despite replication: {:?}",
        failed.len(),
        failed.first()
    );
    let snap = router.snapshot();
    assert_eq!(snap.requests, (CLIENTS * (PHASE1 + PHASE2)) as u64);
    assert_eq!(snap.failures, 0);
    // with R=2 and only one backend down, every key keeps a live
    // replica — no portion may be lost, so nothing degrades
    assert_eq!(
        snap.degraded, 0,
        "one dead replica out of R=2 must not degrade any reply"
    );

    // a key owned (rank-0) by the dead backend must still get a full
    // reply with facts, served from its surviving replica — on a
    // partitioned fleet only a replica can do this
    if let Some(victim) = names
        .iter()
        .find(|n| router.ring().owner(entity_key(n.as_str())) == Some(0))
    {
        let reply = router.query(&format!("tell me about {victim}"));
        assert!(is_ok(&reply), "{reply}");
        assert_eq!(reply.get("degraded"), Some(&Json::Bool(false)), "{reply}");
        assert!(
            reply.get("facts").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "surviving replica must actually hold the key: {reply}"
        );
        let after = router.snapshot();
        assert!(
            after.failovers + after.replica_hits > 0,
            "dead owner must be served off-owner"
        );
    }
}

#[test]
fn full_index_mode_still_survives_a_kill_via_ring_wide_failover() {
    // The PR-3 deployment (replication_factor = 0, every backend a full
    // index) keeps its own failover branch: candidates are the WHOLE
    // ring, healthy-first. Guard it with a compact kill-mid-load pass so
    // a regression in that branch can't hide behind the replicated kill
    // test above.
    let ds = dataset(4);
    let (mut backends, router) = cluster(&ds, 3, &quiet_cfg());
    let names = entity_names(&ds);
    let queries: Vec<String> = names
        .iter()
        .take(12)
        .map(|n| format!("tell me about {n}"))
        .collect();

    const CLIENTS: usize = 2;
    const PHASE1: usize = 3;
    const PHASE2: usize = 8;
    let mid_load = Arc::new(Barrier::new(CLIENTS + 1));
    let failures = Mutex::new(Vec::<String>::new());
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let router = router.clone();
            let mid_load = mid_load.clone();
            let queries = &queries;
            let failures = &failures;
            s.spawn(move || {
                let mut serve = |i: usize| {
                    let q = &queries[(c * 5 + i) % queries.len()];
                    let reply = router.query(q);
                    if !is_ok(&reply) {
                        failures.lock().unwrap().push(reply.to_string());
                    }
                };
                for i in 0..PHASE1 {
                    serve(i);
                }
                mid_load.wait();
                for i in PHASE1..PHASE1 + PHASE2 {
                    serve(i);
                }
            });
        }
        mid_load.wait();
        backends[0].kill();
    });

    let failed = failures.into_inner().unwrap();
    assert!(
        failed.is_empty(),
        "{} full-index queries failed despite ring-wide failover: {:?}",
        failed.len(),
        failed.first()
    );
    let snap = router.snapshot();
    assert_eq!(snap.failures, 0);
    // a key owned by the dead backend is rescued by ANY live backend
    // (full indexes), counted as a failover
    if let Some(victim) = names
        .iter()
        .find(|n| router.ring().owner(entity_key(n.as_str())) == Some(0))
    {
        let before = router.snapshot().failovers;
        let reply = router.query(&format!("tell me about {victim}"));
        assert!(is_ok(&reply), "{reply}");
        assert!(
            router.snapshot().failovers > before,
            "dead owner must be failed over ring-wide"
        );
    }
}

#[test]
fn replicated_writes_reach_quorum_and_apply_on_every_replica() {
    let ds = dataset(6);
    let (mut backends, router) =
        partitioned_cluster(&ds, 3, 2, &quiet_cfg());

    // pick a real entity and one of its true occurrences
    let forest = ds.build_forest();
    let victim = "cardiology";
    let addr = forest
        .entity_id(victim)
        .map(|id| forest.scan_addresses(id)[0])
        .expect("cardiology occurs in the hospital forest");

    let probe = format!("tell me about {victim}");
    let facts_of = |reply: &Json| -> f64 {
        reply.get("facts").and_then(Json::as_f64).unwrap_or(0.0)
    };
    let before = router.query(&probe);
    assert!(is_ok(&before), "{before}");
    assert!(facts_of(&before) > 0.0, "{before}");

    // delete broadcasts to BOTH replicas (write fan-out + full quorum):
    // afterwards no replica can serve the key, from anyone's view
    let reply = router.remove(victim);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(reply.get("replicas").and_then(Json::as_f64), Some(2.0));
    assert_eq!(reply.get("acks").and_then(Json::as_f64), Some(2.0));
    assert_eq!(reply.get("applied").and_then(Json::as_f64), Some(2.0));
    let gone = router.query(&probe);
    assert!(is_ok(&gone), "{gone}");
    assert_eq!(facts_of(&gone), 0.0, "deleted everywhere: {gone}");

    // re-insert through the router: both replicas index it again
    let reply = router.update(victim, addr.tree, addr.node);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(reply.get("acks").and_then(Json::as_f64), Some(2.0));
    let back = router.query(&probe);
    assert!(facts_of(&back) > 0.0, "re-inserted: {back}");

    // kill one replica of the key: the write quorum (default = all
    // targets) can no longer be met, and the reply names the dead
    // backend so the failure is debuggable client-side
    let key = entity_key(victim);
    let second_replica = router.ring().replicas(key, 2)[1];
    backends[second_replica].kill();
    let dead_addr = backends[second_replica].addr.clone();
    let reply = router.remove(victim);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert_eq!(reply.get("acks").and_then(Json::as_f64), Some(1.0));
    let errors = reply.get("errors").and_then(Json::as_arr).expect("errors");
    assert!(
        errors.iter().any(|e| {
            e.get("backend").and_then(Json::as_str) == Some(dead_addr.as_str())
        }),
        "quorum failure must name the dead backend: {reply}"
    );
    let snap = router.snapshot();
    assert!(snap.write_fanouts >= 3, "{snap:?}");
    assert_eq!(snap.quorum_fails, 1, "{snap:?}");
}

#[test]
fn partitioned_r1_degrades_with_backend_attribution() {
    // Without replication (R=1) a partitioned fleet loses a key's only
    // holder when its backend dies: the reply degrades — and must say
    // WHICH mentions were lost and WHICH backend failed. This is the
    // failure mode the R=2 kill test proves replication eliminates.
    let ds = dataset(6);
    let (mut backends, router) =
        partitioned_cluster(&ds, 3, 1, &quiet_cfg());
    let names = entity_names(&ds);

    // two mentions owned by two different backends
    let a = names
        .iter()
        .find(|n| router.ring().owner(entity_key(n.as_str())) == Some(0))
        .expect("some key owned by backend 0");
    let b = names
        .iter()
        .find(|n| router.ring().owner(entity_key(n.as_str())) != Some(0))
        .expect("some key owned elsewhere");

    backends[0].kill();
    let dead_addr = backends[0].addr.clone();

    // the scattered query survives, degraded, with full attribution
    let reply = router.query(&format!(
        "describe the hierarchy around {a} and {b}"
    ));
    assert!(is_ok(&reply), "{reply}");
    assert_eq!(reply.get("degraded"), Some(&Json::Bool(true)), "{reply}");
    let missing: Vec<&str> = reply
        .get("missing_entities")
        .and_then(Json::as_arr)
        .expect("missing_entities")
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert!(missing.contains(&a.as_str()), "{reply}");
    let failed: Vec<&str> = reply
        .get("failed_backends")
        .and_then(Json::as_arr)
        .expect("failed_backends")
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert_eq!(failed, vec![dead_addr.as_str()], "{reply}");
    assert!(router.snapshot().degraded >= 1);

    // a single-mention query for the lost key is a terminal failure
    // that names the backend
    let reply = router.query(&format!("tell me about {a}"));
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert_eq!(
        reply.get("backend").and_then(Json::as_str),
        Some(dead_addr.as_str()),
        "terminal failures must name the failing backend: {reply}"
    );
}

#[test]
fn joining_backend_warms_up_under_live_load_and_shrinks_incumbents() {
    // The ISSUE-5 acceptance scenario: a backend is added to a LIVE
    // key-partitioned R=2 fleet under Zipf query load. The joiner is
    // started `--joining`-style (index built EMPTY — every key it ends
    // up serving must have arrived through the warm-up handoff), the
    // router admits it only after the warm-up completes, zero queries
    // fail before/during/after admission, and the incumbents' post-drop
    // live index memory shrinks toward the ~R/(N+1) bound.
    let ds = dataset(6);
    let (backends, router) = partitioned_cluster(&ds, 3, 2, &quiet_cfg());
    let names = entity_names(&ds);
    let forest = ds.build_forest();
    let workload = cft_rag::data::workload::Workload::generate(
        &forest,
        cft_rag::data::workload::WorkloadConfig {
            entities_per_query: 1,
            queries: 32,
            zipf_s: 1.2,
            deep_bias: 0.0,
            ..Default::default()
        },
    );
    let live_before: usize = backends
        .iter()
        .map(|b| b.coordinator.live_index_bytes())
        .sum();

    // the joiner: bound first (the new partition hashes the final
    // address list), index built EMPTY awaiting the handoff
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind joiner");
    let joiner_addr = listener.local_addr().unwrap().to_string();
    let mut new_list: Vec<String> =
        backends.iter().map(|b| b.addr.clone()).collect();
    new_list.push(joiner_addr.clone());
    let joiner = TestBackend::start_on(
        &ds,
        listener,
        RagConfig {
            replication_factor: 2,
            key_partition: Some(
                KeyPartition::joining(new_list.clone(), 3, 2)
                    .expect("joining partition"),
            ),
            ..RagConfig::default()
        },
    );
    for name in &names {
        assert!(
            joiner.coordinator.dump_entity(name).is_empty(),
            "{name}: a --joining backend must start with an empty index"
        );
    }

    const CLIENTS: usize = 4;
    const PHASE1: usize = 5;
    const PHASE2: usize = 20;
    let mid_load = Arc::new(Barrier::new(CLIENTS + 1));
    let failures = Mutex::new(Vec::<String>::new());
    let join_reply = std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let router = router.clone();
            let mid_load = mid_load.clone();
            let workload = &workload;
            let failures = &failures;
            s.spawn(move || {
                let mut serve = |i: usize| {
                    let q =
                        &workload.queries[(c * 7 + i) % workload.queries.len()];
                    let reply = router.query(&q.text);
                    if !is_ok(&reply) {
                        failures.lock().unwrap().push(reply.to_string());
                    }
                };
                for i in 0..PHASE1 {
                    serve(i);
                }
                // all clients are mid-load when the join starts and
                // keep querying straight through warm-up + admission
                mid_load.wait();
                for i in PHASE1..PHASE1 + PHASE2 {
                    serve(i);
                }
            });
        }
        mid_load.wait();
        router.join(&joiner_addr)
    });

    assert_eq!(
        join_reply.get("ok"),
        Some(&Json::Bool(true)),
        "{join_reply}"
    );
    assert_eq!(join_reply.get("epoch").and_then(Json::as_f64), Some(1.0));
    assert!(
        join_reply
            .get("keys_streamed")
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            > 0.0,
        "warm-up must stream the joiner's slice: {join_reply}"
    );
    let failed = failures.into_inner().unwrap();
    assert!(
        failed.is_empty(),
        "{} queries failed across the join: {:?}",
        failed.len(),
        failed.first()
    );
    assert_eq!(router.num_backends(), 4);
    assert_eq!(router.ring_epoch(), 1);

    // warm-up completeness: the joiner holds EXACTLY its newly owned
    // slice — every key whose new replica set contains it (streamed via
    // handoff into an index that started empty), and nothing else
    let ring = router.ring();
    let mut owned = 0usize;
    for name in &names {
        let is_replica = ring.replicas(entity_key(name), 2).contains(&3);
        let held = !joiner.coordinator.dump_entity(name).is_empty();
        assert_eq!(held, is_replica, "{name}: joiner warm-up slice");
        owned += usize::from(is_replica);
    }
    assert!(owned > 0, "the joiner must own some of {} keys", names.len());

    // serving after admission: queries keep succeeding, and a key the
    // joiner now co-serves retrieves real facts
    let snap = router.snapshot();
    assert_eq!(snap.failures, 0);
    assert_eq!(snap.ring_epoch, 1);
    assert_eq!(snap.joins, 1);
    assert!(snap.rebalanced_keys > 0);
    let victim = names
        .iter()
        .find(|n| ring.replicas(entity_key(n.as_str()), 2).contains(&3))
        .expect("some key lands on the joiner");
    let reply = router.query(&format!("tell me about {victim}"));
    assert!(is_ok(&reply), "{reply}");
    assert!(
        reply.get("facts").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
        "{reply}"
    );

    // the ~R/(N+1) bound: incumbents dropped their disowned keys, so
    // fleet-wide live index memory shrinks (2/3 -> 2/4 of the keyspace
    // per incumbent) even though a fourth index now exists
    assert!(
        join_reply
            .get("keys_dropped")
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            > 0.0,
        "incumbents must reclaim disowned keys: {join_reply}"
    );
    let live_after: usize = backends
        .iter()
        .map(|b| b.coordinator.live_index_bytes())
        .sum();
    assert!(
        live_after < live_before,
        "incumbent live index bytes must shrink: {live_before} -> {live_after}"
    );
}

#[test]
fn drain_hands_sole_replica_keys_to_next_ranked_owners() {
    // The mirror operation: at R=1 every key has exactly ONE holder, so
    // draining a backend without handoff would lose its whole slice.
    // After `drain`, the leaving backend's keys must be served by their
    // next-ranked owners — provably, because the drained process is
    // killed afterwards and every key still retrieves facts.
    let ds = dataset(6);
    let (mut backends, router) =
        partitioned_cluster(&ds, 3, 1, &quiet_cfg());
    let names = entity_names(&ds);

    // sanity: some keys are solely held by backend 0
    let pre_ring = router.ring();
    let victim_keys: Vec<&String> = names
        .iter()
        .filter(|n| pre_ring.owner(entity_key(n.as_str())) == Some(0))
        .collect();
    assert!(!victim_keys.is_empty(), "backend 0 owns nothing?");

    let drain_addr = backends[0].addr.clone();
    let reply = router.drain(&drain_addr);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(reply.get("action").and_then(Json::as_str), Some("drain"));
    assert_eq!(reply.get("epoch").and_then(Json::as_f64), Some(1.0));
    assert!(
        reply
            .get("keys_streamed")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as usize
            >= victim_keys.len(),
        "every sole-replica key must be handed off: {reply}"
    );
    assert_eq!(router.num_backends(), 2);
    assert_eq!(router.ring_epoch(), 1);
    let snap = router.snapshot();
    assert_eq!(snap.drains, 1);
    assert_eq!(snap.backends.len(), 2, "drained slot removed");

    // the drained process can now really go away...
    backends[0].kill();
    // ...and every one of its former sole-replica keys still answers
    // with facts, served by its next-ranked owner
    for name in victim_keys {
        let reply = router.query(&format!("tell me about {name}"));
        assert!(is_ok(&reply), "{name}: {reply}");
        assert!(
            reply.get("facts").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
            "{name} lost its facts in the drain: {reply}"
        );
    }
    let snap = router.snapshot();
    assert_eq!(snap.failures, 0, "zero failed queries through the drain");
}

#[test]
fn prober_observes_load_and_readmits_restarted_backend() {
    let ds = dataset(4);
    let cfg = RouterConfig {
        probe_interval: Duration::from_millis(40),
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_secs(10),
        ..RouterConfig::default()
    };
    let (mut backends, router) = cluster(&ds, 2, &cfg);

    // real queries raise the backend-side request counters; the prober
    // reads them through the \x01stats control line
    for _ in 0..3 {
        assert!(is_ok(&router.query("describe the hierarchy around cardiology")));
    }
    // poll-wait with a fresh, generous deadline per phase (CI can be
    // slow); no bare sleeps — see `util::wait`
    fn wait_until(what: &str, cond: impl FnMut() -> bool) {
        cft_rag::util::wait::require(what, Duration::from_secs(10), cond);
    }
    let observed = |router: &Router| -> u64 {
        router
            .backends()
            .iter()
            .map(|b| b.health().observed_load())
            .sum()
    };
    wait_until("prober sees the backend load", || observed(&router) >= 3);
    assert!(router.backends().iter().all(|b| b.health().probes() > 0));

    // kill backend 0: the prober demotes it without any query traffic
    let addr = backends[0].addr.clone();
    backends[0].kill();
    wait_until("prober demotes the dead backend", || {
        !router.backends()[0].health().is_healthy()
    });

    // restart on the same port: the prober re-admits automatically
    backends[0] = TestBackend::start(&ds, &addr);
    wait_until("prober re-admits the recovered backend", || {
        router.backends()[0].health().is_healthy()
    });
    assert!(router.backends()[0].health().readmissions() >= 1);
    // and the fleet serves as before
    assert!(is_ok(&router.query("what is the parent unit of oncology")));
}

#[test]
fn warm_restart_rejoins_at_recorded_epoch_with_delta_catch_up() {
    // The ISSUE-9 acceptance scenario: a DURABLE backend (--data-dir)
    // is killed and restarted warm from its snapshot + op log. The
    // prober must re-admit it at the partition epoch recorded on disk
    // (no operator repartition), and `\x01join` of the already-member
    // address must take the REJOIN path: no epoch roll, and only the
    // writes it missed while down are streamed — O(delta), not the
    // O(index) full handoff a cold join performs.
    let data_dir = std::env::temp_dir()
        .join(format!("cft-warm-rejoin-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&data_dir);

    let ds = dataset(6);
    let cfg = RouterConfig {
        probe_interval: Duration::from_millis(40),
        connect_timeout: Duration::from_secs(2),
        request_timeout: Duration::from_secs(10),
        // writes must still ack while one of R=2 replicas is down —
        // that is precisely the delta the rejoin exists to close
        write_quorum: 1,
        ..RouterConfig::default()
    };

    // 3-backend R=2 partitioned fleet; backend 0 is the durable one
    let listeners: Vec<TcpListener> = (0..3)
        .map(|_| TcpListener::bind("127.0.0.1:0").expect("bind"))
        .collect();
    let addrs: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let mut backends: Vec<TestBackend> = listeners
        .into_iter()
        .enumerate()
        .map(|(i, listener)| {
            let cfg = RagConfig {
                replication_factor: 2,
                key_partition: Some(
                    KeyPartition::new(addrs.clone(), i, 2).expect("partition"),
                ),
                data_dir: (i == 0).then(|| data_dir.clone()),
                ..RagConfig::default()
            };
            TestBackend::start_on(&ds, listener, cfg)
        })
        .collect();
    let names = entity_names(&ds);
    let router_cfg = RouterConfig {
        backends: addrs.clone(),
        replication_factor: 2,
        ..cfg
    };
    let router = Arc::new(
        Router::connect(names.iter().map(String::as_str), &router_cfg)
            .expect("router"),
    );
    fn wait_until(what: &str, cond: impl FnMut() -> bool) {
        cft_rag::util::wait::require(what, Duration::from_secs(10), cond);
    }

    // roll the fleet off epoch 0 so "re-admitted at the RECORDED epoch"
    // is a real assertion: drain backend 2 → epoch 1, survivors
    // repartitioned over [addr0, addr1] (R=2 of 2: every key on both);
    // backend 0 logs the Epoch(1) record durably
    let reply = router.drain(&addrs[2]);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(router.ring_epoch(), 1);
    let rebalanced_before_rejoin = router.snapshot().rebalanced_keys;

    // entities with real forest occurrences, for valid write addresses
    let forest = ds.build_forest();
    let occupied: Vec<&String> = names
        .iter()
        .filter(|n| {
            forest
                .entity_id(n)
                .is_some_and(|id| !forest.scan_addresses(id).is_empty())
        })
        .collect();
    assert!(occupied.len() >= 3, "need 3 occupied entities");
    let (e_pre, e_dead_del, e_dead_ins) =
        (occupied[0], occupied[1], occupied[2]);

    // an acked PRE-kill write: this delete must survive the restart
    // purely from disk (a plain forest rebuild would resurrect it)
    let reply = router.remove(e_pre);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");

    // kill the durable backend (final snapshot cut on clean stop; the
    // SIGKILL-mid-churn variant lives in tests/crash_consistency.rs)
    backends[0].kill();
    wait_until("prober demotes the dead durable backend", || {
        !router.backends()[0].health().is_healthy()
    });

    // the WHILE-DEAD delta: one delete, one brand-new occurrence —
    // acked by the surviving replica alone (write_quorum = 1)
    let reply = router.remove(e_dead_del);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    let ins_id = forest.entity_id(e_dead_ins).unwrap();
    let have: Vec<_> = forest.scan_addresses(ins_id);
    let novel = forest
        .entity_id(e_pre)
        .map(|id| forest.scan_addresses(id))
        .unwrap()
        .into_iter()
        .find(|a| !have.contains(a))
        .expect("an address not already indexed for e_dead_ins");
    let reply = router.update(e_dead_ins, novel.tree, novel.node);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");

    // warm restart on the same address, same data dir, the post-drain
    // partition — the recovery path restores snapshot + log and stamps
    // the RECORDED epoch (1), with no repartition from anyone
    let listener = TcpListener::bind(&addrs[0]).expect("rebind backend 0");
    backends[0] = TestBackend::start_on(
        &ds,
        listener,
        RagConfig {
            replication_factor: 2,
            key_partition: Some(
                KeyPartition::new(
                    vec![addrs[0].clone(), addrs[1].clone()],
                    0,
                    2,
                )
                .expect("partition"),
            ),
            data_dir: Some(data_dir.clone()),
            ..RagConfig::default()
        },
    );
    let warm = &backends[0].coordinator;
    assert_eq!(
        warm.partition_epoch(),
        1,
        "recovery must re-stamp the partition at the recorded epoch"
    );
    let d = warm.durability().expect("durable backend has counters");
    assert!(d.snapshot_loaded, "restart must load the final snapshot");
    assert!(
        warm.dump_entity(e_pre).is_empty(),
        "the acked pre-kill delete must hold from disk"
    );
    assert!(
        !warm.dump_entity(e_dead_del).is_empty(),
        "sanity: the while-dead delete is exactly what rejoin must close"
    );

    // the prober re-admits off the recorded epoch alone
    wait_until("prober re-admits the warm-restarted backend", || {
        router.backends()[0].health().is_healthy()
    });
    assert!(router.backends()[0].health().readmissions() >= 1);

    // \x01join of an existing member = REJOIN: same epoch, no drop
    // pass, and ONLY the while-dead delta streamed
    let reply = router.join(&addrs[0]);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(
        reply.get("action").and_then(Json::as_str),
        Some("rejoin"),
        "{reply}"
    );
    assert_eq!(
        reply.get("epoch").and_then(Json::as_f64),
        Some(1.0),
        "a rejoin must not roll the epoch: {reply}"
    );
    assert_eq!(router.ring_epoch(), 1);
    assert_eq!(router.num_backends(), 2);
    let streamed = reply
        .get("keys_streamed")
        .and_then(Json::as_f64)
        .unwrap_or(f64::NAN) as usize;
    // the delta is one replayed key (the while-dead insert; the
    // while-dead delete reconciles by deletion, streaming nothing) —
    // a full handoff would stream every owned key (R=2 of 2: ALL keys)
    assert!(
        streamed >= 1 && streamed < names.len() / 2,
        "rejoin must stream O(delta), not O(index): {streamed} of {} keys",
        names.len()
    );
    let rejoin_keys =
        router.snapshot().rebalanced_keys - rebalanced_before_rejoin;
    assert!(
        (rejoin_keys as usize) < names.len() / 2,
        "stats must show delta-sized catch-up, got {rejoin_keys}"
    );

    // the rejoined backend converged on the while-dead writes
    assert!(
        warm.dump_entity(e_dead_del).is_empty(),
        "rejoin must apply the missed delete"
    );
    assert!(
        warm.dump_entity(e_dead_ins).contains(&novel),
        "rejoin must replay the missed insert"
    );
    let _ = std::fs::remove_dir_all(&data_dir);
}

#[test]
fn elasticity_contracts_are_named_and_enforced() {
    use cft_rag::router::contracts;

    // the six ROADMAP invariants exist as named executable assertions,
    // and every test build enforces them (debug_assertions) — a release
    // soak can force them with `--features contracts`
    assert!(contracts::enabled(), "test builds must enforce the contracts");
    assert_eq!(
        contracts::ALL,
        [
            contracts::SERVING_SET_FULLY_INDEXED,
            contracts::EPOCH_GATED_MEMBERSHIP,
            contracts::MINIMAL_KEY_MOVEMENT,
            contracts::DUAL_WRITE_COVERAGE,
            contracts::SINGLE_FLIGHT_REBALANCE,
            contracts::CACHE_EPOCH_COHERENT,
        ]
    );

    let ds = dataset(4);
    let (backends, router) = partitioned_cluster(&ds, 3, 2, &quiet_cfg());
    assert_eq!(router.ring_epoch(), 0);

    // A joiner whose partition claims the WRONG slice (index 0 of the
    // new ring instead of its own): it NACKs the warm-up inserts, the
    // join aborts mid-handoff, and the wired check_abort_unchanged
    // assertion proves the abort left the serving membership untouched
    // [single-flight-rebalance: "a failed rebalance changes nothing"].
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind joiner");
    let joiner_addr = listener.local_addr().unwrap().to_string();
    let mut new_list: Vec<String> =
        backends.iter().map(|b| b.addr.clone()).collect();
    new_list.push(joiner_addr.clone());
    let mut bad_joiner = TestBackend::start_on(
        &ds,
        listener,
        RagConfig {
            replication_factor: 2,
            key_partition: Some(
                KeyPartition::new(new_list.clone(), 0, 2)
                    .expect("mis-sliced partition"),
            ),
            ..RagConfig::default()
        },
    );
    let reply = router.join(&joiner_addr);
    assert_eq!(
        reply.get("ok"),
        Some(&Json::Bool(false)),
        "a joiner NACKing its warm-up must abort the join: {reply}"
    );
    assert_eq!(router.ring_epoch(), 0, "failed join must not roll the epoch");
    assert_eq!(router.num_backends(), 3, "failed join must not admit");
    bad_joiner.kill();

    // The same address rejoining correctly runs the full wired gauntlet:
    // window-open [epoch-gated-membership + single-flight-rebalance],
    // the movement plan [serving-set-fully-indexed half: every changed
    // key is streamed; minimal-key-movement half: nothing else is],
    // per-routing replica-set sanity [serving-set-fully-indexed], and
    // the epoch commit [epoch-gated-membership]. (dual-write-coverage
    // fires on writes inside the window; unit-tested in
    // `router::contracts`.)
    let listener = TcpListener::bind(&joiner_addr).expect("rebind joiner");
    let _joiner = TestBackend::start_on(
        &ds,
        listener,
        RagConfig {
            replication_factor: 2,
            key_partition: Some(
                KeyPartition::joining(new_list, 3, 2)
                    .expect("joining partition"),
            ),
            ..RagConfig::default()
        },
    );
    let reply = router.join(&joiner_addr);
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(router.ring_epoch(), 1);
    assert_eq!(router.num_backends(), 4);
    assert!(is_ok(&router.query("describe the hierarchy around cardiology")));
}

#[test]
fn reply_cache_hits_hot_queries_and_stays_fresh_across_writes_and_joins() {
    // The ISSUE-10 acceptance scenario: Zipf-skewed load on a
    // key-partitioned R=2 fleet with the reply cache ON hits >50%, a
    // quorum write and a live `\x01join` mid-stream invalidate
    // synchronously (no reply ever reflects pre-write or pre-roll
    // state), and the cache counters surface all of it. The
    // cache-epoch-coherent contract is armed throughout (test build):
    // any cross-epoch cache touch would panic this test.
    let ds = dataset(6);
    let cfg = RouterConfig {
        cache_capacity_bytes: 8 * 1024 * 1024,
        ..quiet_cfg()
    };
    let (backends, router) = partitioned_cluster(&ds, 3, 2, &cfg);
    let forest = ds.build_forest();

    // Zipf s=1.1 single-entity workload: the hot head repeats, which
    // is exactly the traffic the cache exists to absorb
    let workload = cft_rag::data::workload::Workload::generate(
        &forest,
        cft_rag::data::workload::WorkloadConfig {
            entities_per_query: 1,
            queries: 16,
            zipf_s: 1.1,
            deep_bias: 0.0,
            ..Default::default()
        },
    );
    for _ in 0..4 {
        for q in &workload.queries {
            assert!(is_ok(&router.query(&q.text)));
        }
    }
    let snap = router.snapshot();
    let served = snap.cache_hits + snap.cache_misses;
    assert_eq!(served, 64, "every query consults the enabled cache");
    assert!(
        snap.cache_hits as f64 / served as f64 > 0.5,
        "hot Zipf load must hit >50%: {} of {served}",
        snap.cache_hits
    );
    assert!(snap.cache_bytes > 0, "admitted entries must report bytes");

    // Staleness probe, delete edition: cache the reply, delete through
    // the router, re-ask the SAME query — the delete's ack must have
    // already evicted it, so the answer reflects the delete at once.
    let victim = "cardiology";
    let addr = forest
        .entity_id(victim)
        .map(|id| forest.scan_addresses(id)[0])
        .expect("cardiology occurs in the hospital forest");
    let probe = format!("tell me about {victim}");
    let facts_of = |reply: &Json| -> f64 {
        reply.get("facts").and_then(Json::as_f64).unwrap_or(0.0)
    };
    assert!(facts_of(&router.query(&probe)) > 0.0);
    assert!(is_ok(&router.query(&probe)), "prime the cache");
    let inv_before = router.snapshot().cache_invalidations;
    assert!(is_ok(&router.remove(victim)));
    assert!(
        router.snapshot().cache_invalidations > inv_before,
        "an acked write must count an invalidation"
    );
    let gone = router.query(&probe);
    assert!(is_ok(&gone), "{gone}");
    assert_eq!(facts_of(&gone), 0.0, "stale reply after delete: {gone}");

    // Insert edition: the now-cached zero-fact reply must die with the
    // re-insert's ack, not linger as a stale hole.
    assert!(is_ok(&router.query(&probe)), "cache the empty answer");
    assert!(is_ok(&router.update(victim, addr.tree, addr.node)));
    let back = router.query(&probe);
    assert!(facts_of(&back) > 0.0, "stale empty reply after insert: {back}");

    // Live join mid-load: clients hammer the hot head straight through
    // the membership change. Zero failures, the epoch roll flushes the
    // cache, and post-join hits re-fill under the new epoch.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind joiner");
    let joiner_addr = listener.local_addr().unwrap().to_string();
    let mut new_list: Vec<String> =
        backends.iter().map(|b| b.addr.clone()).collect();
    new_list.push(joiner_addr.clone());
    let _joiner = TestBackend::start_on(
        &ds,
        listener,
        RagConfig {
            replication_factor: 2,
            key_partition: Some(
                KeyPartition::joining(new_list, 3, 2)
                    .expect("joining partition"),
            ),
            ..RagConfig::default()
        },
    );
    const CLIENTS: usize = 2;
    let mid_load = Arc::new(Barrier::new(CLIENTS + 1));
    let failures = Mutex::new(Vec::<String>::new());
    let flushes_before = router.snapshot().cache_invalidations;
    let join_reply = std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let router = router.clone();
            let mid_load = mid_load.clone();
            let workload = &workload;
            let failures = &failures;
            s.spawn(move || {
                mid_load.wait();
                for i in 0..16 {
                    let q = &workload.queries
                        [(c * 7 + i) % workload.queries.len()];
                    let reply = router.query(&q.text);
                    if !is_ok(&reply) {
                        failures.lock().unwrap().push(reply.to_string());
                    }
                }
            });
        }
        mid_load.wait();
        router.join(&joiner_addr)
    });
    assert_eq!(
        join_reply.get("ok"),
        Some(&Json::Bool(true)),
        "{join_reply}"
    );
    let failed = failures.into_inner().unwrap();
    assert!(
        failed.is_empty(),
        "{} queries failed across the cached join: {:?}",
        failed.len(),
        failed.first()
    );
    assert_eq!(router.ring_epoch(), 1);
    assert!(
        router.snapshot().cache_invalidations > flushes_before,
        "the epoch roll must flush the cache"
    );
    let reply = router.query(&probe);
    assert!(is_ok(&reply), "{reply}");
    assert!(facts_of(&reply) > 0.0, "post-join epoch-1 refill: {reply}");
}
