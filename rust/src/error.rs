//! Crate-wide error type.
//!
//! The `Display`/`Error` impls are hand-rolled: the crate is
//! deliberately dependency-free (see `Cargo.toml`), so `thiserror` is
//! not available. Semantics match the previous derive exactly —
//! prefixed messages per variant, transparent passthrough for `Io`.

use std::fmt;

/// All failure modes of the CFT-RAG stack.
#[derive(Debug)]
pub enum CftError {
    /// Artifact loading / manifest problems (run `make artifacts`).
    Artifact(String),

    /// PJRT / XLA runtime failures.
    Runtime(String),

    /// Bad request or configuration.
    Config(String),

    /// Coordinator lifecycle problems (channel closed, worker died).
    Coordinator(String),

    /// I/O.
    Io(std::io::Error),
}

impl fmt::Display for CftError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CftError::Artifact(m) => write!(f, "artifact error: {m}"),
            CftError::Runtime(m) => write!(f, "runtime error: {m}"),
            CftError::Config(m) => write!(f, "config error: {m}"),
            CftError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            // transparent: the io::Error's own message, no prefix
            CftError::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for CftError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CftError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CftError {
    fn from(e: std::io::Error) -> Self {
        CftError::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for CftError {
    fn from(e: xla::Error) -> Self {
        CftError::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, CftError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive() {
        assert_eq!(
            CftError::Coordinator("queue closed".into()).to_string(),
            "coordinator error: queue closed"
        );
        assert_eq!(
            CftError::Artifact("missing".into()).to_string(),
            "artifact error: missing"
        );
        // Io is transparent: no prefix, source() exposes the inner error
        let io = CftError::from(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        assert_eq!(io.to_string(), "gone");
        assert!(std::error::Error::source(&io).is_some());
    }
}
