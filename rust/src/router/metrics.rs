//! Router-level metrics, rebuilt on the unified [`Registry`]
//! (`obs/registry.rs`): every fleet-wide counter is a registered
//! series (scrapeable via the router's `\x01metrics` exposition), and
//! per-backend latency uses the registry's lock-free [`Histogram`]
//! type — the hand-rolled percentile plumbing this module used to
//! duplicate with `coordinator/metrics.rs` is gone. Ring membership is
//! elastic (`router/rebalance.rs`), so the per-backend slots grow on
//! join and are remapped on drain, and the snapshot carries the
//! serving ring's membership epoch plus the rebalance counters
//! (`joins`/`drains`/keys streamed/keys dropped/dual writes).
//! `docs/OPERATIONS.md` explains what to do when each counter moves.
//! The `\x01stats` JSON payload keeps its historical field names.
//!
//! # Examples
//!
//! ```
//! use std::time::Duration;
//! use cft_rag::router::metrics::RouterMetrics;
//!
//! let m = RouterMetrics::new(2);
//! m.record_query(true);
//! m.record_backend(0, true, Duration::from_millis(2));
//! let info = vec![("a:1".to_string(), true), ("b:2".to_string(), true)];
//! let snap = m.snapshot(&info, 0);
//! assert_eq!(snap.requests, 1);
//! assert_eq!(snap.ring_epoch, 0);
//! assert_eq!(snap.backends[0].requests, 1);
//! // the \x01stats payload is this snapshot as one JSON object
//! assert!(snap.to_json().to_string().contains("\"ring_epoch\""));
//! ```

use std::time::Duration;

use crate::obs::{Counter, Gauge, Histogram, Registry};
use crate::sync::{Arc, Mutex};
use crate::util::json::Json;

/// Snapshot of one backend's counters at an instant.
#[derive(Clone, Debug)]
pub struct BackendMetricsSnapshot {
    pub addr: String,
    /// Health at snapshot time (from the backend's [`HealthState`]).
    ///
    /// [`HealthState`]: crate::router::health::HealthState
    pub healthy: bool,
    pub requests: u64,
    pub failures: u64,
    pub latency_mean_s: f64,
    pub latency_p99_s: f64,
}

/// Snapshot of the router's counters at an instant.
#[derive(Clone, Debug)]
pub struct RouterMetricsSnapshot {
    /// Queries answered (one per `Router::query`, merged or not).
    pub requests: u64,
    /// Queries that could not produce an `ok` reply at all.
    pub failures: u64,
    /// Queries fanned out to more than one backend.
    pub fanouts: u64,
    /// Sub-requests served by a backend other than the key's owner.
    pub failovers: u64,
    /// Replicated-mode sub-requests served by a non-owner replica
    /// *without* any candidate failing first — the least-loaded load
    /// balancer's choice, not a rescue.
    pub replica_hits: u64,
    /// Merged replies missing at least one portion.
    pub degraded: u64,
    /// Broadcast writes (`\x01insert`/`\x01delete` fan-outs).
    pub write_fanouts: u64,
    /// Broadcast writes that missed their ack quorum.
    pub quorum_fails: u64,
    /// Backends rebalanced into the serving ring (`\x01join`).
    pub joins: u64,
    /// Backends rebalanced out of the serving ring (`\x01drain`).
    pub drains: u64,
    /// Entity keys streamed during warm-up/handoff rebalances.
    pub rebalanced_keys: u64,
    /// Disowned keys reclaimed by post-rebalance drop passes.
    pub dropped_keys: u64,
    /// Writes additionally applied to the incoming epoch's replica set
    /// while a rebalance was in flight (mid-rebalance consistency).
    pub dual_writes: u64,
    /// Backend exchanges cut off by their end-to-end request deadline
    /// on the outbound reactor. Stamped by `Router::snapshot` from the
    /// [`NetDriver`](crate::reactor::client::NetDriver) counter — the
    /// sink itself always reports 0 here.
    pub deadlines_expired: u64,
    /// Queries answered straight from the reply cache.
    pub cache_hits: u64,
    /// Cache-eligible queries that had to hit the backends.
    pub cache_misses: u64,
    /// Cache entries displaced by the frequency-sketch admission
    /// policy (capacity pressure, not correctness).
    pub cache_evictions: u64,
    /// Invalidation events: one per acked write broadcast and one per
    /// epoch-roll flush (correctness, not capacity).
    pub cache_invalidations: u64,
    /// Approximate heap bytes held by the reply cache at snapshot time.
    pub cache_bytes: u64,
    /// The serving ring's membership epoch at snapshot time.
    pub ring_epoch: u64,
    pub backends: Vec<BackendMetricsSnapshot>,
}

impl RouterMetricsSnapshot {
    /// Queries per second over an elapsed window.
    pub fn throughput(&self, elapsed: Duration) -> f64 {
        if elapsed.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.requests as f64 / elapsed.as_secs_f64()
        }
    }

    /// JSON form (the router front door's `\x01stats` payload).
    pub fn to_json(&self) -> Json {
        let backends = self
            .backends
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("addr", Json::Str(b.addr.clone())),
                    ("healthy", Json::Bool(b.healthy)),
                    ("requests", Json::Num(b.requests as f64)),
                    ("failures", Json::Num(b.failures as f64)),
                    ("latency_mean_s", Json::Num(b.latency_mean_s)),
                    ("latency_p99_s", Json::Num(b.latency_p99_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failures", Json::Num(self.failures as f64)),
            ("fanouts", Json::Num(self.fanouts as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("replica_hits", Json::Num(self.replica_hits as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("write_fanouts", Json::Num(self.write_fanouts as f64)),
            ("quorum_fails", Json::Num(self.quorum_fails as f64)),
            ("joins", Json::Num(self.joins as f64)),
            ("drains", Json::Num(self.drains as f64)),
            ("rebalanced_keys", Json::Num(self.rebalanced_keys as f64)),
            ("dropped_keys", Json::Num(self.dropped_keys as f64)),
            ("dual_writes", Json::Num(self.dual_writes as f64)),
            (
                "deadlines_expired",
                Json::Num(self.deadlines_expired as f64),
            ),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("cache_evictions", Json::Num(self.cache_evictions as f64)),
            (
                "cache_invalidations",
                Json::Num(self.cache_invalidations as f64),
            ),
            ("cache_bytes", Json::Num(self.cache_bytes as f64)),
            ("ring_epoch", Json::Num(self.ring_epoch as f64)),
            ("backends", Json::Arr(backends)),
        ])
    }
}

/// One backend's slot: request/failure tallies plus its latency
/// histogram. Plain integers are fine — the slot vector itself sits
/// behind a mutex because join/drain grow and shift it.
#[derive(Debug, Default)]
struct BackendSlot {
    requests: u64,
    failures: u64,
    latency: Histogram,
}

/// Thread-shared router metrics sink.
#[derive(Clone, Debug)]
pub struct RouterMetrics {
    registry: Arc<Registry>,
    requests: Arc<Counter>,
    failures: Arc<Counter>,
    fanouts: Arc<Counter>,
    failovers: Arc<Counter>,
    replica_hits: Arc<Counter>,
    degraded: Arc<Counter>,
    write_fanouts: Arc<Counter>,
    quorum_fails: Arc<Counter>,
    joins: Arc<Counter>,
    drains: Arc<Counter>,
    rebalanced_keys: Arc<Counter>,
    dropped_keys: Arc<Counter>,
    dual_writes: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    cache_evictions: Arc<Counter>,
    cache_invalidations: Arc<Counter>,
    /// Reply-cache resident bytes — a gauge, stamped by the router
    /// after every cache mutation so the `\x01metrics` exposition and
    /// the `\x01stats` snapshot agree.
    cache_bytes: Arc<Gauge>,
    /// Aggregate backend-exchange latency across the whole fleet (the
    /// per-backend split lives in the slots / `\x01stats` JSON; the
    /// registry has no label dimension by design).
    exchange: Arc<Histogram>,
    backends: Arc<Mutex<Vec<BackendSlot>>>,
}

impl RouterMetrics {
    /// New sink for `nbackends` backends.
    pub fn new(nbackends: usize) -> Self {
        let registry = Arc::new(Registry::new());
        let c = |name: &str, help: &str| registry.counter(name, help);
        RouterMetrics {
            requests: c("cft_router_requests_total", "queries answered"),
            failures: c("cft_router_failures_total", "queries with no ok reply"),
            fanouts: c("cft_router_fanouts_total", "queries scattered to >1 backend"),
            failovers: c("cft_router_failovers_total", "sub-requests served off-owner"),
            replica_hits: c(
                "cft_router_replica_hits_total",
                "sub-requests served by a replica chosen by load",
            ),
            degraded: c("cft_router_degraded_total", "merged replies missing a portion"),
            write_fanouts: c("cft_router_write_fanouts_total", "broadcast write fan-outs"),
            quorum_fails: c("cft_router_quorum_fails_total", "writes missing ack quorum"),
            joins: c("cft_router_joins_total", "backends rebalanced into the ring"),
            drains: c("cft_router_drains_total", "backends rebalanced out of the ring"),
            rebalanced_keys: c(
                "cft_router_rebalanced_keys_total",
                "entity keys streamed by rebalances",
            ),
            dropped_keys: c(
                "cft_router_dropped_keys_total",
                "disowned keys reclaimed after rebalance",
            ),
            dual_writes: c(
                "cft_router_dual_writes_total",
                "writes dual-applied during a rebalance",
            ),
            cache_hits: c(
                "cft_router_cache_hits_total",
                "queries answered from the reply cache",
            ),
            cache_misses: c(
                "cft_router_cache_misses_total",
                "cache-eligible queries that hit the backends",
            ),
            cache_evictions: c(
                "cft_router_cache_evictions_total",
                "reply-cache entries displaced by admission",
            ),
            cache_invalidations: c(
                "cft_router_cache_invalidations_total",
                "reply-cache invalidation events (writes + epoch rolls)",
            ),
            cache_bytes: registry.gauge(
                "cft_router_cache_bytes",
                "approximate reply-cache resident bytes",
            ),
            exchange: registry.histogram(
                "cft_router_backend_exchange_seconds",
                "backend exchange round-trip latency, all backends",
            ),
            backends: Arc::new(Mutex::new(
                (0..nbackends).map(|_| BackendSlot::default()).collect(),
            )),
            registry,
        }
    }

    /// The registry backing this sink — the router's `\x01metrics`
    /// exposition renders it (plus point-in-time gauges).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Record one completed `Router::query` (ok or not).
    pub fn record_query(&self, ok: bool) {
        self.requests.inc();
        if !ok {
            self.failures.inc();
        }
    }

    /// Record a multi-backend fanned-out query.
    pub fn record_fanout(&self) {
        self.fanouts.inc();
    }

    /// Record a sub-request served off-owner.
    pub fn record_failover(&self) {
        self.failovers.inc();
    }

    /// Record a sub-request served by a non-owner replica by load
    /// choice (replicated mode, nothing failed first).
    pub fn record_replica_hit(&self) {
        self.replica_hits.inc();
    }

    /// Record a merged reply with a missing portion.
    pub fn record_degraded(&self) {
        self.degraded.inc();
    }

    /// Record one broadcast write fan-out.
    pub fn record_write_fanout(&self) {
        self.write_fanouts.inc();
    }

    /// Record a broadcast write that missed its ack quorum.
    pub fn record_quorum_fail(&self) {
        self.quorum_fails.inc();
    }

    /// Record a completed `\x01join` rebalance: `keys` streamed to the
    /// warmed joiner.
    pub fn record_join(&self, keys: u64) {
        self.joins.inc();
        self.rebalanced_keys.add(keys);
    }

    /// Record a completed `\x01drain` rebalance: `keys` handed off to
    /// their next-ranked owners.
    pub fn record_drain(&self, keys: u64) {
        self.drains.inc();
        self.rebalanced_keys.add(keys);
    }

    /// Record disowned keys reclaimed by a post-rebalance drop pass.
    pub fn record_dropped_keys(&self, keys: u64) {
        self.dropped_keys.add(keys);
    }

    /// Record a write dual-applied to the incoming epoch's replica set
    /// while a rebalance was in flight.
    pub fn record_dual_write(&self) {
        self.dual_writes.inc();
    }

    /// Record a query answered straight from the reply cache.
    pub fn record_cache_hit(&self) {
        self.cache_hits.inc();
    }

    /// Record a cache-eligible query that had to hit the backends.
    pub fn record_cache_miss(&self) {
        self.cache_misses.inc();
    }

    /// Record `n` entries displaced by the cache's admission policy.
    pub fn record_cache_evictions(&self, n: u64) {
        self.cache_evictions.add(n);
    }

    /// Record one invalidation event (an acked write broadcast or an
    /// epoch-roll flush).
    pub fn record_cache_invalidation(&self) {
        self.cache_invalidations.inc();
    }

    /// Stamp the reply cache's resident bytes (after any mutation).
    pub fn set_cache_bytes(&self, bytes: usize) {
        self.cache_bytes.set(bytes as f64);
    }

    /// Grow the per-backend slots to `n` (a backend joined the ring;
    /// indexes are append-only on join, so existing slots keep their
    /// history).
    pub fn ensure_backends(&self, n: usize) {
        let mut slots = self.backends.lock().unwrap();
        while slots.len() < n {
            slots.push(BackendSlot::default());
        }
    }

    /// Remove the per-backend slot `idx` (a backend drained out of the
    /// ring; later slots shift down, matching the new address list).
    ///
    /// Known smear: queries in flight across the swap still hold the
    /// previous membership snapshot and report with *old* indices, so
    /// for that instant their samples land one slot off (or, past the
    /// end, are dropped). The counters are monitoring-grade; a
    /// handful of cross-attributed samples per drain is accepted
    /// rather than tagging every sample with a membership generation.
    pub fn remove_backend(&self, idx: usize) {
        let mut slots = self.backends.lock().unwrap();
        if idx < slots.len() {
            slots.remove(idx);
        }
    }

    /// Record one backend round trip. `idx` beyond the current slot
    /// count is ignored — a query thread holding the pre-drain
    /// membership snapshot may report against a removed slot; dropping
    /// (or, one slot lower, smearing — see
    /// [`remove_backend`](RouterMetrics::remove_backend)) that
    /// monitoring-grade sample beats panicking the query path.
    pub fn record_backend(&self, idx: usize, ok: bool, latency: Duration) {
        self.exchange.record_duration(latency);
        let mut slots = self.backends.lock().unwrap();
        let Some(b) = slots.get_mut(idx) else { return };
        b.requests += 1;
        if !ok {
            b.failures += 1;
        }
        b.latency.record_duration(latency);
    }

    /// Snapshot against backend identities: `info[i]` is backend `i`'s
    /// `(addr, healthy-now)` — health lives with the backends, not in
    /// this sink, so the caller (the router) joins the two —
    /// and `ring_epoch` is the serving ring's membership epoch. The
    /// zip is tolerant of a transient length mismatch (membership can
    /// change between reading the ring and locking the sink): only the
    /// common prefix is reported.
    pub fn snapshot(
        &self,
        info: &[(String, bool)],
        ring_epoch: u64,
    ) -> RouterMetricsSnapshot {
        let slots = self.backends.lock().unwrap();
        RouterMetricsSnapshot {
            requests: self.requests.get(),
            failures: self.failures.get(),
            fanouts: self.fanouts.get(),
            failovers: self.failovers.get(),
            replica_hits: self.replica_hits.get(),
            degraded: self.degraded.get(),
            write_fanouts: self.write_fanouts.get(),
            quorum_fails: self.quorum_fails.get(),
            joins: self.joins.get(),
            drains: self.drains.get(),
            rebalanced_keys: self.rebalanced_keys.get(),
            dropped_keys: self.dropped_keys.get(),
            dual_writes: self.dual_writes.get(),
            deadlines_expired: 0,
            cache_hits: self.cache_hits.get(),
            cache_misses: self.cache_misses.get(),
            cache_evictions: self.cache_evictions.get(),
            cache_invalidations: self.cache_invalidations.get(),
            cache_bytes: self.cache_bytes.get() as u64,
            ring_epoch,
            backends: slots
                .iter()
                .zip(info)
                .map(|(b, (addr, healthy))| BackendMetricsSnapshot {
                    addr: addr.clone(),
                    healthy: *healthy,
                    requests: b.requests,
                    failures: b.failures,
                    latency_mean_s: b.latency.mean(),
                    latency_p99_s: b.latency.quantile(0.99),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots_per_backend() {
        let m = RouterMetrics::new(2);
        m.record_query(true);
        m.record_query(false);
        m.record_fanout();
        m.record_failover();
        m.record_replica_hit();
        m.record_replica_hit();
        m.record_degraded();
        m.record_write_fanout();
        m.record_quorum_fail();
        m.record_join(12);
        m.record_drain(5);
        m.record_dropped_keys(9);
        m.record_dual_write();
        m.record_cache_hit();
        m.record_cache_miss();
        m.record_cache_miss();
        m.record_cache_evictions(3);
        m.record_cache_invalidation();
        m.set_cache_bytes(4096);
        m.record_backend(0, true, Duration::from_millis(2));
        m.record_backend(1, false, Duration::from_millis(4));
        let info = vec![("a:1".to_string(), true), ("b:2".to_string(), false)];
        let s = m.snapshot(&info, 2);
        assert_eq!(s.requests, 2);
        assert_eq!(s.failures, 1);
        assert_eq!(s.fanouts, 1);
        assert_eq!(s.failovers, 1);
        assert_eq!(s.replica_hits, 2);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.write_fanouts, 1);
        assert_eq!(s.quorum_fails, 1);
        assert_eq!(s.joins, 1);
        assert_eq!(s.drains, 1);
        assert_eq!(s.rebalanced_keys, 17, "join keys + drain keys");
        assert_eq!(s.dropped_keys, 9);
        assert_eq!(s.dual_writes, 1);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 2);
        assert_eq!(s.cache_evictions, 3);
        assert_eq!(s.cache_invalidations, 1);
        assert_eq!(s.cache_bytes, 4096);
        assert_eq!(s.ring_epoch, 2);
        assert_eq!(s.backends[0].requests, 1);
        assert_eq!(s.backends[0].failures, 0);
        assert!(s.backends[0].healthy);
        assert_eq!(s.backends[1].failures, 1);
        assert!(!s.backends[1].healthy);
        assert!(s.backends[1].latency_mean_s > 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let m = RouterMetrics::new(1);
        m.record_query(true);
        m.record_backend(0, true, Duration::from_micros(500));
        let s = m.snapshot(&[("x:1".to_string(), true)], 0);
        let back = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(back.get("requests").and_then(Json::as_f64), Some(1.0));
        for field in [
            "replica_hits",
            "write_fanouts",
            "quorum_fails",
            "joins",
            "drains",
            "rebalanced_keys",
            "dropped_keys",
            "dual_writes",
            "deadlines_expired",
            "cache_hits",
            "cache_misses",
            "cache_evictions",
            "cache_invalidations",
            "cache_bytes",
            "ring_epoch",
        ] {
            assert_eq!(
                back.get(field).and_then(Json::as_f64),
                Some(0.0),
                "{field} missing from the stats payload"
            );
        }
        let backends = back.get("backends").unwrap().as_arr().unwrap();
        assert_eq!(backends[0].get("addr").and_then(Json::as_str), Some("x:1"));
        assert_eq!(backends[0].get("healthy"), Some(&Json::Bool(true)));
    }

    #[test]
    fn throughput_math() {
        let m = RouterMetrics::new(0);
        for _ in 0..50 {
            m.record_query(true);
        }
        let s = m.snapshot(&[], 0);
        assert!((s.throughput(Duration::from_secs(5)) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn membership_changes_grow_and_remap_backend_slots() {
        let m = RouterMetrics::new(2);
        m.record_backend(0, true, Duration::from_millis(1));
        m.record_backend(1, true, Duration::from_millis(1));
        m.record_backend(1, true, Duration::from_millis(1));
        // join: slot 2 appears with empty history
        m.ensure_backends(3);
        m.record_backend(2, true, Duration::from_millis(1));
        let info: Vec<(String, bool)> = ["a:1", "b:2", "c:3"]
            .iter()
            .map(|a| (a.to_string(), true))
            .collect();
        let s = m.snapshot(&info, 1);
        assert_eq!(
            [s.backends[0].requests, s.backends[1].requests, s.backends[2].requests],
            [1, 2, 1]
        );
        // drain of slot 0: later slots shift down with their history
        m.remove_backend(0);
        let info: Vec<(String, bool)> = ["b:2", "c:3"]
            .iter()
            .map(|a| (a.to_string(), true))
            .collect();
        let s = m.snapshot(&info, 2);
        assert_eq!(s.backends.len(), 2);
        assert_eq!(s.backends[0].requests, 2, "b:2 kept its history");
        assert_eq!(s.backends[1].requests, 1);
        // a stale index from the previous membership is dropped, not a
        // panic — and a transiently longer info list only reports the
        // common prefix
        m.record_backend(9, true, Duration::from_millis(1));
        let longer: Vec<(String, bool)> = ["b:2", "c:3", "ghost:9"]
            .iter()
            .map(|a| (a.to_string(), true))
            .collect();
        assert_eq!(m.snapshot(&longer, 2).backends.len(), 2);
    }

    #[test]
    fn aggregate_exchange_histogram_feeds_the_registry() {
        let m = RouterMetrics::new(1);
        m.record_backend(0, true, Duration::from_millis(3));
        // even an out-of-range slot index still lands in the aggregate
        m.record_backend(9, true, Duration::from_millis(3));
        let text = m.registry().render();
        assert!(text.contains("# TYPE cft_router_backend_exchange_seconds histogram"));
        assert!(text.contains("cft_router_backend_exchange_seconds_count 2"));
    }

    #[test]
    fn cache_series_flow_to_the_prometheus_exposition() {
        let m = RouterMetrics::new(1);
        m.record_cache_hit();
        m.record_cache_invalidation();
        m.set_cache_bytes(1536);
        let text = m.registry().render();
        assert!(text.contains("cft_router_cache_hits_total 1"));
        assert!(text.contains("cft_router_cache_misses_total 0"));
        assert!(text.contains("cft_router_cache_invalidations_total 1"));
        assert!(text.contains("cft_router_cache_bytes 1536"));
    }
}
