//! Distributed shard router: scatter-gather serving over N independent
//! TCP coordinators (PR 3 — the ROADMAP's "Distributed shards" item).
//!
//! The per-shard independence of the in-process
//! [`ShardedCuckooFilter`](crate::filter::sharded::ShardedCuckooFilter)
//! — no operation ever coordinates across shards — maps 1:1 onto
//! multi-process sharding. This subsystem is that map: a thin,
//! dependency-free L4 in front of any number of `cft-rag serve`
//! processes, routing by **entity-key ownership** with the same hash
//! family the filter shards with
//! ([`rendezvous_score`](crate::filter::fingerprint::rendezvous_score)),
//! so routing a key to a backend and sharding it inside that backend
//! never correlate.
//!
//! ```text
//!            clients (newline-delimited queries, JSON-line replies)
//!                │
//!                ▼
//!        ┌──────────────────┐   cft-rag route --backends a,b,c
//!        │      Router      │   (or embed Router in-process)
//!        │  ┌────────────┐  │
//!        │  │ Gazetteer  │  │  query → entity mentions
//!        │  └─────┬──────┘  │
//!        │  ┌─────▼──────┐  │
//!        │  │ ShardRing  │  │  mention → owning backend (rendezvous)
//!        │  └─────┬──────┘  │
//!        │  ┌─────▼──────┐  │  single owner: route whole query
//!        │  │  scatter   │  │  multi owner: fan out owned mentions,
//!        │  └─┬───┬───┬──┘  │  merge deterministically
//!        │ ┌──▼┐┌─▼─┐┌▼──┐  │
//!        │ │CP ││CP ││CP │◄─┼── ConnPool + HealthState per backend
//!        │ └─┬─┘└─┬─┘└─┬─┘  │    (prober: \x01stats every interval)
//!        └───┼────┼────┼────┘
//!            ▼    ▼    ▼
//!        ┌─────┐┌─────┐┌─────┐
//!        │coord││coord││coord│   coordinator/tcp.rs processes, each
//!        │  A  ││  B  ││  C  │   with its own sharded Cuckoo filter
//!        └─────┘└─────┘└─────┘   (in-process shards ⊂ process shards)
//! ```
//!
//! Failure model: per-backend request timeouts bound the damage of a
//! slow backend to its own portion of a fan-out; transport errors and
//! coordinator refusals walk the ring's deterministic failover order
//! (minimal disruption: only the dead backend's keys move — property-
//! tested in `ring.rs`); a prober re-admits recovered backends. The
//! integration tests (`tests/router_integration.rs`) kill a live
//! backend mid-load and assert zero failed queries.
//!
//! **Replication + partitioned indexes**
//! (`RouterConfig::replication_factor`, ISSUE 4): with `R >= 1`, each
//! entity key lives
//! on its top-R ranked backends only — every backend is started with a
//! matching [`KeyPartition`](crate::rag::config::KeyPartition) and
//! indexes ~`R/N` of the keys. Reads are served by the least-loaded
//! healthy replica with ranked failover inside the replica set; the
//! `\x01insert`/`\x01delete` dynamic updates broadcast to all R
//! replicas and ack-count against `RouterConfig::write_quorum`. The
//! kill-one-backend test runs against partitioned R=2 backends and
//! stays zero-failure *and* zero-degraded. Wire format:
//! `docs/PROTOCOL.md`.
//!
//! **Elastic membership** (ISSUE 5): ring membership is no longer
//! frozen at fleet start — `\x01join <addr>`/`\x01drain <addr>` (or
//! `cft-rag route --admit/--drain`) rebalance backends in and out at
//! runtime with warm-up handoff, partition-epoch rolling, gated
//! admission, and a disowned-key drop pass. The protocol and its
//! mid-rebalance correctness argument live in [`rebalance`]; the
//! operator procedures in `docs/OPERATIONS.md`.

pub mod backend;
pub mod contracts;
pub mod health;
pub mod metrics;
pub mod pool;
pub mod rebalance;
pub mod ring;
pub mod scatter;

pub use backend::Backend;
pub use health::{EpochGate, HealthProber, HealthState};
pub use metrics::{
    BackendMetricsSnapshot, RouterMetrics, RouterMetricsSnapshot,
};
pub use pool::ConnPool;
pub use rebalance::{Membership, RebalanceReport, RingState};
pub use ring::ShardRing;
pub use scatter::Router;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::tcp::{parse_control, ControlLine};
use crate::error::Result;
use crate::util::json::Json;
use crate::util::log;

/// Front-door TCP loop: the router speaks the *same* line protocol as
/// a single coordinator (`coordinator/tcp.rs`, spec in
/// `docs/PROTOCOL.md`), so clients cannot tell one node from a fleet.
/// `\x01stats` returns the router-level snapshot (per-backend
/// health/latency and the serving `ring_epoch` included);
/// `\x01insert`/`\x01delete` become quorum broadcasts to the key's
/// replica set; `\x01join <addr>`/`\x01drain <addr>` run an elastic
/// membership change ([`Router::join`]/[`Router::drain`] — warm-up
/// rebalancing, `router/rebalance.rs`; runbook in
/// `docs/OPERATIONS.md`). Backend-side control lines
/// (`\x01dump`/`\x01repartition`/`\x01purge`) are refused here — the
/// rebalancer drives those against backends directly. Serves until the
/// process dies — the `cft-rag route` CLI path.
pub fn serve(router: Arc<Router>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    log::info!("cft-rag router listening on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(stream) => {
                let r = router.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(r, stream);
                });
            }
            Err(e) => {
                log::warn!("router accept failed (transient): {e}");
                if e.kind() != std::io::ErrorKind::Interrupted {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                }
            }
        }
    }
    Ok(())
}

fn handle_conn(router: Arc<Router>, stream: TcpStream) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        let query = line.trim();
        if query.is_empty() {
            continue;
        }
        if query == ":quit" {
            break;
        }
        let reply = match parse_control(query) {
            Some(Ok(ControlLine::Stats)) => router.snapshot().to_json(),
            Some(Ok(ControlLine::Insert { tree, node, entity })) => {
                router.update(entity, tree, node)
            }
            Some(Ok(ControlLine::Delete { entity })) => router.remove(entity),
            Some(Ok(ControlLine::Join { addr })) => router.join(addr),
            Some(Ok(ControlLine::Drain { addr })) => router.drain(addr),
            Some(Ok(
                ControlLine::Dump { .. }
                | ControlLine::Repartition { .. }
                | ControlLine::Purge,
            )) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::Str(
                        "dump/repartition/purge are backend control \
                         lines; the rebalancer drives them — send \
                         \\x01join/\\x01drain here instead"
                            .into(),
                    ),
                ),
            ]),
            Some(Err(reason)) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(reason)),
            ]),
            None => router.query(query),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}
