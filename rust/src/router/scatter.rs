//! The scatter-gather query path (reply shapes: `docs/PROTOCOL.md`).
//!
//! `Router::query` is the distributed analogue of one coordinator
//! round trip:
//!
//! 1. **Localize** — recognize the query's entity mentions (the same
//!    gazetteer the backends use) and map each to the backends that can
//!    serve it: in replicated mode the mention's R-way **replica set**
//!    (the top-R of the ring's ranked order), otherwise its healthy
//!    owner.
//! 2. **Route** — a query whose entities all share one serving set (or
//!    that mentions none) goes there directly, whole. Otherwise the
//!    query *scatters*: each group of mentions with the same serving
//!    set travels as one sub-request, so the per-backend retrieval +
//!    generation work is the owned share, not the whole query repeated
//!    N times.
//! 3. **Gather** — sub-replies merge deterministically (group order):
//!    entity union sorted, fact counts summed, answers concatenated,
//!    stage times `max`ed (the fan-out ran in parallel).
//!
//! Failure containment: each sub-request walks its candidate order for
//! up to `max_attempts` backends; socket-level errors *and* `ok:false`
//! coordinator replies (queue closed, backend stopping) both trigger
//! the next candidate. In full-index mode (`replication_factor == 0`)
//! the candidates are the whole ring, healthy first. In replicated
//! mode the walk stays **within the replica set** — a non-replica would
//! answer with silently missing facts — and healthy replicas are tried
//! least-loaded first (the `\x01stats` `requests` gauge the prober
//! collects), so hot keys spread across their replicas. Fan-outs
//! multiplex on the router's shared outbound reactor
//! ([`NetDriver`](crate::reactor::client::NetDriver)) — one driver
//! thread runs every concurrent exchange, instead of a blocking thread
//! per sub-request — and every exchange carries an absolute end-to-end
//! deadline (`request_timeout`: connect + write + full reply), so one
//! slow backend can only delay its own portion; if every candidate for a portion
//! fails, the merged reply is flagged `degraded` (with the missing
//! mentions and the failing backends' addresses) rather than failing
//! the query — unless *no* portion succeeded, which is the only path to
//! an `ok:false` reply from the router.
//!
//! **Writes** (`Router::update` / `Router::remove`) broadcast the
//! `\x01insert`/`\x01delete` control line to every backend that indexes
//! the key — the replica set, or the whole fleet in full-index mode —
//! and count per-replica acks against the configured write quorum.
//!
//! When the hot-entity reply cache is enabled
//! (`RouterConfig::cache_capacity_bytes`, `router/cache.rs`), step 1
//! first consults it under the query's membership snapshot: a hit skips
//! the fan-out entirely, a fully served (`ok`, non-degraded) miss is
//! offered back, writes point-invalidate the entity's entries before
//! their ack returns, and a join/drain flushes wholesale on commit and
//! abort alike.

use std::collections::{BTreeMap, BTreeSet};
use std::io;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::tcp::{DELETE_REQUEST, INSERT_REQUEST};
use crate::error::{CftError, Result};
use crate::filter::fingerprint::entity_key;
use crate::nlp::ner::GazetteerNer;
use crate::obs::trace::{self, Sampler, Stage, TraceId};
use crate::rag::config::RouterConfig;
use crate::sync::time::Instant;
use crate::reactor::client::{Exchange, NetDriver};
use crate::router::backend::Backend;
use crate::router::cache::{normalize_entities, ReplyCache};
use crate::router::health::{EpochGate, HealthProber};
use crate::router::metrics::{RouterMetrics, RouterMetricsSnapshot};
use crate::router::rebalance::{
    execute_drain, execute_join, serving_set, Membership, RebalanceCtx,
    RingState,
};
use crate::router::ring::ShardRing;
use crate::util::json::Json;
use crate::util::log;
use crate::util::rng::fnv1a;

/// A failed candidate walk: the terminal error plus the backend that
/// produced it, so error and degraded replies are debuggable from the
/// client side (`None` only when there were no candidates at all).
#[derive(Debug)]
struct SendFailure {
    err: io::Error,
    backend: Option<String>,
}

/// One fan-out portion: the mentions routed to one serving set, and the
/// outcome (serving backend index + its reply).
type Portion = (Vec<String>, std::result::Result<(usize, Json), SendFailure>);

/// One fan-out group's in-progress failover walk: the scatter path
/// advances every unfinished walk one candidate per multiplexed round.
struct GroupWalk {
    ents: Vec<String>,
    line: String,
    candidates: Vec<usize>,
    owner: usize,
    attempt: usize,
    walk_failed: bool,
    outcome: std::result::Result<(usize, Json), SendFailure>,
}

/// The shard router: entity-aware scatter-gather over N coordinator
/// backends. All methods take `&self`; clients query from any number of
/// threads concurrently. Ring membership is **elastic**: [`Router::join`]
/// and [`Router::drain`] rebalance backends in and out at runtime
/// (`router/rebalance.rs`, ops runbook in `docs/OPERATIONS.md`); the
/// query path works against a consistent membership snapshot per query.
pub struct Router {
    membership: Arc<Membership>,
    /// The router config the fleet was connected with — also used to
    /// dial backends that join later.
    cfg: RouterConfig,
    ner: GazetteerNer,
    /// The entity vocabulary, retained for rebalance planning (the key
    /// universe a membership change has to move).
    vocab: Vec<String>,
    metrics: RouterMetrics,
    max_attempts: usize,
    /// R-way replication (0 = full-index backends; see `RouterConfig`).
    replication: usize,
    /// Acks required per broadcast write (already resolved: `0` in the
    /// config means "all targets", resolved per write).
    write_quorum: usize,
    /// Head sampler for distributed request tracing (`\x01t=` wire
    /// propagation; `RouterConfig::trace_sample_every`).
    sampler: Sampler,
    /// Real wall clock (never the model-check shim): uptime is
    /// operator-facing and stamped into `\x01stats`.
    started: std::time::Instant,
    /// Serializes join/drain — one membership change at a time.
    rebalance_lock: Mutex<()>,
    /// Hot-entity reply cache (`router/cache.rs`), keyed on (query,
    /// normalized entity set, membership epoch). Disabled at capacity
    /// 0 (`RouterConfig::cache_capacity_bytes`, the library default).
    cache: ReplyCache,
    /// The shared outbound reactor: every backend exchange — queries,
    /// probes, rebalance streams — multiplexes onto its one thread.
    driver: Arc<NetDriver>,
    _prober: HealthProber,
}

impl Router {
    /// Build a router over `cfg.backends`, recognizing the entity
    /// vocabulary in `entity_names` (normally the forest's interner —
    /// the same names the backends index, so a mention localizes to the
    /// same key on both sides of the wire).
    pub fn connect<'a>(
        entity_names: impl IntoIterator<Item = &'a str>,
        cfg: &RouterConfig,
    ) -> Result<Router> {
        if cfg.backends.is_empty() {
            return Err(CftError::Config(
                "router needs at least one backend address".into(),
            ));
        }
        if cfg.replication_factor > cfg.backends.len() {
            return Err(CftError::Config(format!(
                "replication_factor {} exceeds the {} backends",
                cfg.replication_factor,
                cfg.backends.len()
            )));
        }
        let vocab: Vec<String> =
            entity_names.into_iter().map(str::to_string).collect();
        let ring = ShardRing::new(cfg.backends.iter().cloned());
        let gate = Arc::new(EpochGate::new(0));
        let driver = Arc::new(NetDriver::start()?);
        let backends: Vec<Arc<Backend>> = cfg
            .backends
            .iter()
            .enumerate()
            .map(|(i, addr)| {
                Arc::new(Backend::new(
                    i,
                    addr,
                    cfg,
                    gate.clone(),
                    driver.clone(),
                ))
            })
            .collect();
        let membership =
            Arc::new(Membership::new(ring, backends.clone(), gate));
        let targets: Arc<dyn crate::router::health::ProbeTargets> =
            membership.clone();
        let prober = HealthProber::start(targets, cfg.probe_interval);
        Ok(Router {
            membership,
            cfg: cfg.clone(),
            metrics: RouterMetrics::new(backends.len()),
            ner: GazetteerNer::new(vocab.iter().map(String::as_str)),
            vocab,
            max_attempts: cfg.max_attempts.max(1),
            replication: cfg.replication_factor,
            write_quorum: cfg.write_quorum,
            sampler: Sampler::new(
                cfg.trace_sample_every,
                cfg.slow_query_threshold,
            ),
            started: std::time::Instant::now(),
            rebalance_lock: Mutex::new(()),
            cache: ReplyCache::new(cfg.cache_capacity_bytes),
            driver,
            _prober: prober,
        })
    }

    /// The configured replication factor (0 = full-index backends).
    pub fn replication_factor(&self) -> usize {
        self.replication
    }

    /// Number of fronted backends (current membership).
    pub fn num_backends(&self) -> usize {
        self.membership.load().backends.len()
    }

    /// The routed backends (health inspection, tests) — a snapshot of
    /// the current membership.
    pub fn backends(&self) -> Vec<Arc<Backend>> {
        self.membership.load().backends.clone()
    }

    /// The ownership ring (tests, ops tooling) — a snapshot of the
    /// current membership.
    pub fn ring(&self) -> ShardRing {
        self.membership.load().ring.clone()
    }

    /// The serving membership epoch (bumped by every join/drain).
    pub fn ring_epoch(&self) -> u64 {
        self.membership.epoch()
    }

    /// Metrics sink handle.
    pub fn metrics(&self) -> &RouterMetrics {
        &self.metrics
    }

    /// The reply cache (tests, ops tooling). Inert when
    /// `RouterConfig::cache_capacity_bytes` was 0.
    pub fn cache(&self) -> &ReplyCache {
        &self.cache
    }

    /// The front door's trace head sampler (and slow-query threshold).
    pub fn sampler(&self) -> &Sampler {
        &self.sampler
    }

    /// Wall-clock time since this router was connected — the
    /// `uptime_s` field of the `\x01stats` reply.
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Front-door connection cap (`RouterConfig::max_connections`) —
    /// read by `router::serve` when sizing the serving reactor.
    pub fn max_connections(&self) -> usize {
        self.cfg.max_connections
    }

    /// Front-door idle reap timeout (`RouterConfig::idle_timeout`).
    pub fn idle_timeout(&self) -> Duration {
        self.cfg.idle_timeout
    }

    /// Counters joined with live per-backend health, the serving
    /// membership epoch, and the outbound reactor's deadline-expiry
    /// counter.
    pub fn snapshot(&self) -> RouterMetricsSnapshot {
        let state = self.membership.load();
        let info: Vec<(String, bool)> = state
            .backends
            .iter()
            .map(|b| (b.addr().to_string(), b.health().is_healthy()))
            .collect();
        let mut snap = self.metrics.snapshot(&info, state.epoch);
        snap.deadlines_expired = self.driver.deadlines_expired();
        snap
    }

    /// Rebalance backend `addr` **into** the serving ring (the
    /// `\x01join` front-door line, `cft-rag route --admit`): stream its
    /// newly owned keys from current replicas over the `\x01insert`
    /// handoff transport, roll the fleet to the next partition epoch,
    /// admit it, then run the incumbents' disowned-key drop pass. One
    /// rebalance runs at a time; the reply summarizes what moved.
    pub fn join(&self, addr: &str) -> Json {
        let _guard = self.rebalance_lock.lock().unwrap();
        let ctx = self.rebalance_ctx();
        let result = execute_join(&ctx, addr);
        // epoch-roll flush, commit AND abort paths: on commit the old
        // epoch's replies are dead (the epoch in the key already makes
        // them unreachable — this reclaims the bytes); on abort the
        // warm-up may have partially streamed keys, so flushing is the
        // conservative, always-correct choice
        self.flush_cache_for_epoch_roll();
        match result {
            Ok(report) => report.to_json(),
            Err(e) => {
                log::warn!("join of {addr} failed: {e}");
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e)),
                ])
            }
        }
    }

    /// Rebalance backend `addr` **out of** the serving ring (the
    /// `\x01drain` front-door line, `cft-rag route --drain`): hand its
    /// keys — including sole-replica keys — to their next-ranked
    /// owners, roll the survivors to the next epoch, then remove it.
    /// The drained process can be stopped once this returns `ok`.
    pub fn drain(&self, addr: &str) -> Json {
        let _guard = self.rebalance_lock.lock().unwrap();
        let ctx = self.rebalance_ctx();
        let result = execute_drain(&ctx, addr);
        // epoch-roll flush — same commit-and-abort coverage as `join`
        self.flush_cache_for_epoch_roll();
        match result {
            Ok(report) => report.to_json(),
            Err(e) => {
                log::warn!("drain of {addr} failed: {e}");
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e)),
                ])
            }
        }
    }

    /// Wholesale reply-cache flush after a rebalance attempt (observed
    /// here as the `RingState` swap the `execute_join`/`execute_drain`
    /// call just performed — or didn't, on abort). Runs under the
    /// `rebalance_lock`, so the flush and the epoch roll it answers are
    /// ordered with respect to any other membership change.
    fn flush_cache_for_epoch_roll(&self) {
        if !self.cache.enabled() {
            return;
        }
        self.cache.flush();
        self.metrics.record_cache_invalidation();
        self.metrics.set_cache_bytes(self.cache.bytes());
    }

    fn rebalance_ctx(&self) -> RebalanceCtx<'_> {
        RebalanceCtx {
            membership: &self.membership,
            metrics: &self.metrics,
            cfg: &self.cfg,
            vocab: &self.vocab,
            replication: self.replication,
            driver: &self.driver,
        }
    }

    /// Serve one query through the ring; always returns a reply object
    /// (`ok:false` only when every candidate backend for every portion
    /// failed).
    pub fn query(&self, query: &str) -> Json {
        self.query_traced(query, TraceId::NONE)
    }

    /// [`Router::query`] carrying a request trace: a sampled `trace`
    /// records the router-side stages (`ner`, per-backend `exchange`,
    /// `merge`) and rides the wire to each backend as a `\x01t=` line
    /// prefix, so one trace id names the whole fan-out tree.
    pub fn query_traced(&self, query: &str, trace: TraceId) -> Json {
        let query = query.trim();
        let ner_start = Instant::now();
        let entities = self.ner.recognize(query);
        trace::record(
            trace,
            Stage::Ner,
            entities.len() as u32,
            ner_start,
            ner_start.elapsed(),
        );
        // one consistent membership snapshot per query: a concurrent
        // join/drain swaps the Arc, never mutates what we hold
        let state = self.membership.load();

        // Reply-cache lookup under this snapshot's epoch. On a hit the
        // fan-out is skipped entirely; on a miss the token is kept so
        // the eventual fill can prove no invalidation raced the
        // assembly (see `router/cache.rs`). The epoch in the key plus
        // the contract check inside the cache keep every served entry
        // coherent with the membership snapshot in hand.
        let fill = if self.cache.enabled() {
            let ents = normalize_entities(entities.clone());
            let (hit, token) = self.cache.lookup(query, &ents, state.epoch);
            if let Some(reply) = hit {
                self.metrics.record_cache_hit();
                self.metrics.record_query(
                    reply.get("ok") == Some(&Json::Bool(true)),
                );
                return reply;
            }
            self.metrics.record_cache_miss();
            Some((ents, token))
        } else {
            None
        };

        // Group mentions by the backend set that can serve them: in
        // replicated mode a mention's replica set (mentions sharing a
        // replica set travel together — a partitioned backend only
        // indexes its own keys, so the set, not the owner, is the unit
        // of co-location), otherwise the healthy owner. BTreeMap fixes
        // the merge order deterministically.
        let mut groups: BTreeMap<Vec<usize>, Vec<String>> = BTreeMap::new();
        for e in entities {
            let key = entity_key(&e);
            let set = if self.replication > 0 {
                state.ring.replicas(key, self.replication)
            } else {
                vec![self.owner_of(&state, key)]
            };
            groups.entry(set).or_default().push(e);
        }

        let reply = if groups.len() <= 1 {
            // single-set fast path: the whole query travels as-is
            // (prefixed with the trace id when sampled, so the backend
            // joins the same trace)
            let key = match groups.values().next() {
                Some(ents) => entity_key(&ents[0]),
                // no recognized entities: spread by query text so
                // entity-free traffic still load-balances
                None => fnv1a(query.as_bytes()),
            };
            let owned;
            let line: &str = if trace.is_sampled() {
                owned = trace::prefix_line(trace, query);
                &owned
            } else {
                query
            };
            match self.send_with_failover(&state, key, line, trace) {
                Ok((_, json)) => annotate(json, 1, false),
                Err(e) => error_reply(&e),
            }
        } else {
            self.metrics.record_fanout();
            self.scatter(&state, query, &groups, trace)
        };
        // Failover-aware fill: only a fully served reply is cacheable.
        // A degraded reply is missing a portion's facts — pinning it
        // would keep serving the hole after the backend recovers — and
        // an `ok:false` reply is an error, not an answer.
        if let Some((ents, token)) = fill {
            if reply.get("ok") == Some(&Json::Bool(true))
                && reply.get("degraded") == Some(&Json::Bool(false))
            {
                let outcome =
                    self.cache.admit(query, &ents, state.epoch, &reply, token);
                if outcome.evicted > 0 {
                    self.metrics
                        .record_cache_evictions(outcome.evicted as u64);
                }
                if outcome.admitted {
                    self.metrics.set_cache_bytes(self.cache.bytes());
                }
            }
        }
        self.metrics
            .record_query(reply.get("ok") == Some(&Json::Bool(true)));
        reply
    }

    /// Owner of `key`: highest-ranked healthy backend, or the overall
    /// owner when nothing is currently healthy (the failover walk will
    /// try everything anyway).
    fn owner_of(&self, state: &RingState, key: u64) -> usize {
        state
            .ring
            .owner_where(key, |i| state.backends[i].health().is_healthy())
            .or_else(|| state.ring.owner(key))
            .expect("ring is non-empty by construction")
    }

    /// Fan the mention groups out as one multiplexed batch per failover
    /// round and merge. Round `k` sends every unfinished group's `k`-th
    /// candidate exchange through the outbound reactor in a single
    /// [`NetDriver::exchange_many`] call — the groups' wire time
    /// overlaps on the one driver thread, so a round costs at most one
    /// request deadline even when several backends hang.
    fn scatter(
        &self,
        state: &RingState,
        query: &str,
        groups: &BTreeMap<Vec<usize>, Vec<String>>,
        trace: TraceId,
    ) -> Json {
        let mut walks: Vec<GroupWalk> = groups
            .values()
            .map(|ents| {
                // The sub-request carries only this owner's mentions;
                // its first mention keys the failover walk. Joined with
                // " and ": the backend normalizes punctuation away, so
                // the separator must be a word no entity name contains,
                // or adjacent mentions could bridge into a spurious
                // longer match. A sampled trace prefixes every
                // sub-request line, so the backends' span trees share
                // this request's id.
                let joined = ents.join(" and ");
                let line = if trace.is_sampled() {
                    trace::prefix_line(trace, &joined)
                } else {
                    joined
                };
                let key = entity_key(&ents[0]);
                let (candidates, owner) = self.candidate_walk(state, key);
                GroupWalk {
                    ents: ents.clone(),
                    line,
                    candidates,
                    owner,
                    attempt: 0,
                    walk_failed: false,
                    outcome: Err(SendFailure {
                        err: io::Error::new(
                            io::ErrorKind::NotConnected,
                            "no backend candidates",
                        ),
                        backend: None,
                    }),
                }
            })
            .collect();

        loop {
            // this round's batch: every unfinished walk's next candidate
            let mut round: Vec<usize> = Vec::new();
            let mut specs: Vec<Exchange> = Vec::new();
            for (wi, w) in walks.iter().enumerate() {
                if w.outcome.is_err() && w.attempt < w.candidates.len() {
                    let idx = w.candidates[w.attempt];
                    specs.push(state.backends[idx].exchange_spec(&w.line));
                    round.push(wi);
                }
            }
            if specs.is_empty() {
                break;
            }
            let round_start = Instant::now();
            let results = self.driver.exchange_many(specs);
            for (wi, (raw, elapsed)) in round.into_iter().zip(results) {
                let w = &mut walks[wi];
                let idx = w.candidates[w.attempt];
                w.attempt += 1;
                trace::record(
                    trace,
                    Stage::Exchange,
                    idx as u32,
                    round_start,
                    elapsed,
                );
                let backend = &state.backends[idx];
                match backend.finish_exchange(raw) {
                    Ok(json) => {
                        let ok = json.get("ok") != Some(&Json::Bool(false));
                        self.metrics.record_backend(idx, ok, elapsed);
                        if !ok {
                            w.outcome = Err(refusal(backend, &json));
                            w.walk_failed = true;
                            continue;
                        }
                        self.note_success(idx, w.owner, w.walk_failed);
                        w.outcome = Ok((idx, json));
                    }
                    Err(e) => {
                        self.metrics.record_backend(idx, false, elapsed);
                        w.outcome = Err(SendFailure {
                            err: e,
                            backend: Some(backend.addr().to_string()),
                        });
                        w.walk_failed = true;
                    }
                }
            }
        }

        let parts: Vec<Portion> =
            walks.into_iter().map(|w| (w.ents, w.outcome)).collect();
        let merge_start = Instant::now();
        let reply = self.merge(query, parts);
        trace::record(
            trace,
            Stage::Merge,
            groups.len() as u32,
            merge_start,
            merge_start.elapsed(),
        );
        reply
    }

    /// The failover candidate order for `key`, truncated to
    /// `max_attempts`, plus the key's overall owner:
    ///
    /// * **Full-index mode** (`replication == 0`): the whole ring,
    ///   healthy backends in rank order first.
    /// * **Replicated mode**: only the key's replica set — a
    ///   non-replica would answer `ok:true` with silently missing facts
    ///   — with the healthy replicas ordered least-loaded first (the
    ///   `\x01stats` `requests` gauge; stable sort keeps rank order on
    ///   ties, so an unprobed fleet behaves like ranked failover).
    ///
    /// Unhealthy candidates still follow within `max_attempts` — a
    /// marked-down backend may have just come back, and trying it last
    /// costs nothing when everything else is gone.
    fn candidate_walk(
        &self,
        state: &RingState,
        key: u64,
    ) -> (Vec<usize>, usize) {
        let backends = &state.backends;
        let ranked = if self.replication > 0 {
            state.ring.replicas(key, self.replication)
        } else {
            state.ring.ranked(key)
        };
        // one health read per candidate: reading twice (a healthy pass
        // then an unhealthy pass) would let a concurrent health flip
        // duplicate a candidate and crowd a live one out of the
        // max_attempts window
        let (mut order, unhealthy): (Vec<usize>, Vec<usize>) = ranked
            .iter()
            .copied()
            .partition(|&i| backends[i].health().is_healthy());
        if self.replication > 0 {
            // Load = the backend's cumulative `requests` gauge from the
            // last `\x01stats` probe. Two knowing trade-offs: it is a
            // lifetime counter, so a freshly restarted replica looks
            // idle until it catches up (bounded: it *is* the coldest
            // node and catches up fast); and with probing disabled it
            // stays 0 everywhere, degrading to plain rank order — never
            // to a wrong answer, since every candidate is a replica.
            order.sort_by_key(|&i| backends[i].health().observed_load());
        }
        order.extend(unhealthy);
        order.truncate(self.max_attempts);
        (order, ranked[0])
    }

    /// Bookkeeping for a walk that ended in a success:
    /// rescued-after-failure is a failover; merely serving off-owner
    /// (the replicated load balancer's choice) is a replica hit.
    fn note_success(&self, idx: usize, owner: usize, walk_failed: bool) {
        if self.replication > 0 {
            if walk_failed {
                self.metrics.record_failover();
            } else if idx != owner {
                self.metrics.record_replica_hit();
            }
        } else if idx != owner {
            self.metrics.record_failover();
        }
    }

    /// Try `line` against the candidates for `key` in
    /// [`candidate_walk`](Router::candidate_walk) order, sequentially —
    /// the single-portion path; each attempt still multiplexes on the
    /// outbound reactor under its end-to-end deadline. An `ok:false`
    /// protocol reply is treated like a transport failure for
    /// candidate-walking purposes, but does *not* demote the backend's
    /// health (it answered; the coordinator refused).
    fn send_with_failover(
        &self,
        state: &RingState,
        key: u64,
        line: &str,
        trace: TraceId,
    ) -> std::result::Result<(usize, Json), SendFailure> {
        let backends = &state.backends;
        let (order, owner) = self.candidate_walk(state, key);
        let mut walk_failed = false;
        let mut last = SendFailure {
            err: io::Error::new(
                io::ErrorKind::NotConnected,
                "no backend candidates",
            ),
            backend: None,
        };
        for idx in order {
            let t0 = Instant::now();
            let outcome = backends[idx].request(line);
            trace::record(
                trace,
                Stage::Exchange,
                idx as u32,
                t0,
                t0.elapsed(),
            );
            match outcome {
                Ok(json) => {
                    let ok = json.get("ok") != Some(&Json::Bool(false));
                    self.metrics.record_backend(idx, ok, t0.elapsed());
                    if !ok {
                        last = refusal(&backends[idx], &json);
                        walk_failed = true;
                        continue;
                    }
                    self.note_success(idx, owner, walk_failed);
                    return Ok((idx, json));
                }
                Err(e) => {
                    self.metrics.record_backend(idx, false, t0.elapsed());
                    last = SendFailure {
                        err: e,
                        backend: Some(backends[idx].addr().to_string()),
                    };
                    walk_failed = true;
                }
            }
        }
        Err(last)
    }

    /// Deterministic merge of the fan-out's portions (already in group
    /// order — `scatter` walks a `BTreeMap`).
    fn merge(
        &self,
        query: &str,
        parts: Vec<Portion>,
    ) -> Json {
        let mut entities: BTreeSet<String> = BTreeSet::new();
        let mut answers: Vec<String> = Vec::new();
        let mut facts = 0.0;
        let mut retrieval_us: f64 = 0.0;
        let mut total_ms: f64 = 0.0;
        let mut served = 0usize;
        let mut missing: Vec<String> = Vec::new();
        let mut failed_backends: BTreeSet<String> = BTreeSet::new();
        let mut last_err = String::new();
        let mut last_err_backend: Option<String> = None;

        for (ents, outcome) in parts {
            match outcome {
                Ok((_, json)) => {
                    served += 1;
                    if let Some(arr) =
                        json.get("entities").and_then(Json::as_arr)
                    {
                        entities.extend(
                            arr.iter()
                                .filter_map(Json::as_str)
                                .map(str::to_string),
                        );
                    }
                    if let Some(a) = json.get("answer").and_then(Json::as_str)
                    {
                        if !a.is_empty() {
                            answers.push(a.to_string());
                        }
                    }
                    facts +=
                        json.get("facts").and_then(Json::as_f64).unwrap_or(0.0);
                    retrieval_us = retrieval_us.max(
                        json.get("retrieval_us")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    );
                    total_ms = total_ms.max(
                        json.get("total_ms")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    );
                }
                Err(f) => {
                    missing.extend(ents);
                    last_err = f.err.to_string();
                    if let Some(addr) = &f.backend {
                        failed_backends.insert(addr.clone());
                    }
                    last_err_backend = f.backend;
                }
            }
        }

        if served == 0 {
            log::error!("query {query:?}: every portion failed ({last_err})");
            return error_reply(&SendFailure {
                err: io::Error::other(last_err),
                backend: last_err_backend,
            });
        }
        let degraded = !missing.is_empty();
        if degraded {
            self.metrics.record_degraded();
            log::warn!(
                "degraded reply for {query:?}: no backend served {missing:?} \
                 (backends {failed_backends:?}: {last_err})"
            );
        }
        let mut reply = annotate(
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("answer", Json::Str(answers.join("\n"))),
                (
                    "entities",
                    Json::Arr(
                        entities.into_iter().map(Json::Str).collect(),
                    ),
                ),
                ("facts", Json::Num(facts)),
                ("retrieval_us", Json::Num(retrieval_us)),
                ("total_ms", Json::Num(total_ms)),
            ]),
            served,
            degraded,
        );
        if degraded {
            if let Json::Obj(m) = &mut reply {
                m.insert(
                    "missing_entities".into(),
                    Json::Arr(missing.into_iter().map(Json::Str).collect()),
                );
                // which backends lost the portions — clients debug a
                // degraded reply without access to the router's logs
                m.insert(
                    "failed_backends".into(),
                    Json::Arr(
                        failed_backends.into_iter().map(Json::Str).collect(),
                    ),
                );
            }
        }
        reply
    }

    /// Broadcast a dynamic entity-index **insert** (`\x01insert`, see
    /// `docs/PROTOCOL.md`): register one occurrence of `entity` at
    /// `(tree, node)` on every backend that indexes the key — its
    /// replica set, or the whole fleet in full-index mode — and count
    /// per-replica acks against the write quorum.
    pub fn update(&self, entity: &str, tree: u32, node: u32) -> Json {
        self.broadcast(
            entity,
            &format!("{INSERT_REQUEST} {tree} {node} {entity}"),
        )
    }

    /// Broadcast a dynamic entity-index **delete** (`\x01delete`, paper
    /// Algorithm 2) to every backend that indexes the key, counting
    /// acks against the write quorum.
    pub fn remove(&self, entity: &str) -> Json {
        self.broadcast(entity, &format!("{DELETE_REQUEST} {entity}"))
    }

    /// The replicated write path: send `line` to all of `entity`'s
    /// index holders in parallel, ack-count, and report quorum. The
    /// reply carries `ok` (quorum reached), `replicas` (targets),
    /// `acks`, `applied` (acks that changed state), `quorum`, and a
    /// per-backend `errors` array when anything failed.
    ///
    /// While a rebalance is in flight (`Router::join`/`drain`), the
    /// write is **dual-applied**: besides the current epoch's targets
    /// it is sent, best-effort, to every backend the *incoming* epoch's
    /// serving set adds — so a write landing mid-handoff cannot be
    /// missing from the new owner after admission. Dual-write acks do
    /// not count toward the quorum (the serving epoch's replicas are
    /// the durability contract); failures are logged and counted
    /// (`dual_writes` only counts sends).
    fn broadcast(&self, entity: &str, line: &str) -> Json {
        // The protocol is one line per request: an entity containing a
        // newline (or the \x01 control prefix) would desynchronize the
        // pooled backend connections — reject before anything is sent.
        if entity.is_empty() || entity.contains(['\n', '\r', '\x01']) {
            return Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::Str(format!(
                        "invalid entity for a dynamic update: {entity:?}"
                    )),
                ),
            ]);
        }
        let state = self.membership.load();
        let key = entity_key(entity);
        let targets: Vec<usize> = if self.replication > 0 {
            state.ring.replicas(key, self.replication)
        } else {
            (0..state.backends.len()).collect()
        };
        self.metrics.record_write_fanout();
        let quorum = if self.write_quorum == 0 {
            targets.len()
        } else {
            self.write_quorum.min(targets.len())
        };

        // mid-rebalance dual writes: the incoming epoch's additions
        let extras: Vec<Arc<Backend>> = match &state.pending {
            Some(p) => serving_set(&p.ring, self.replication, key)
                .into_iter()
                .map(|i| p.backends[i].clone())
                .filter(|b| {
                    !targets
                        .iter()
                        .any(|&t| state.backends[t].addr() == b.addr())
                })
                .collect(),
            None => Vec::new(),
        };
        if let Some(p) = &state.pending {
            crate::router::contracts::check_dual_write_coverage(
                &p.ring,
                self.replication,
                key,
                |a| {
                    targets.iter().any(|&t| state.backends[t].addr() == a)
                        || extras.iter().any(|b| b.addr() == a)
                },
            );
        }

        // one multiplexed batch: the best-effort dual writes to the
        // incoming epoch's additions ride along with the quorum
        // targets' exchanges in the same driver round
        let mut specs: Vec<Exchange> =
            Vec::with_capacity(extras.len() + targets.len());
        for extra in &extras {
            self.metrics.record_dual_write();
            specs.push(extra.exchange_spec(line));
        }
        for &idx in &targets {
            specs.push(state.backends[idx].exchange_spec(line));
        }
        let mut results = self.driver.exchange_many(specs).into_iter();
        for extra in &extras {
            let (raw, _) = results.next().expect("one result per spec");
            if let Err(e) = extra.finish_exchange(raw) {
                log::warn!(
                    "dual write of {line:?} to joining backend {} failed \
                     (the handoff replay will restore it): {e}",
                    extra.addr()
                );
            }
        }
        let outcomes: Vec<(usize, io::Result<Json>)> = targets
            .iter()
            .map(|&idx| {
                let (raw, elapsed) =
                    results.next().expect("one result per spec");
                let res = state.backends[idx].finish_exchange(raw);
                let ok = matches!(
                    &res,
                    Ok(j) if j.get("ok") != Some(&Json::Bool(false))
                );
                self.metrics.record_backend(idx, ok, elapsed);
                (idx, res)
            })
            .collect();

        // Per-key cache eviction *before* the quorum ack returns: the
        // backends above have already applied (or refused) the write,
        // so dropping the entity's cached replies here means a client
        // that saw this ack can never read the pre-write reply — the
        // write-ack-implies-invalidated promise of docs/PROTOCOL.md.
        // Invalidate even on a missed quorum: any applied replica makes
        // the cached replies stale. The cache's fill token also fences
        // any in-flight fill that read pre-write backend state.
        if self.cache.enabled() {
            self.cache.invalidate_entity(entity);
            self.metrics.record_cache_invalidation();
            self.metrics.set_cache_bytes(self.cache.bytes());
        }

        let mut acks = 0usize;
        let mut applied = 0usize;
        let mut errors: Vec<Json> = Vec::new();
        for (idx, res) in outcomes {
            let addr = state.backends[idx].addr();
            match res {
                Ok(json) if json.get("ok") != Some(&Json::Bool(false)) => {
                    acks += 1;
                    if json.get("applied") == Some(&Json::Bool(true)) {
                        applied += 1;
                    }
                }
                Ok(json) => errors.push(Json::obj(vec![
                    ("backend", Json::Str(addr.to_string())),
                    (
                        "error",
                        Json::Str(
                            json.get("error")
                                .and_then(Json::as_str)
                                .unwrap_or("backend refused")
                                .to_string(),
                        ),
                    ),
                ])),
                Err(e) => errors.push(Json::obj(vec![
                    ("backend", Json::Str(addr.to_string())),
                    ("error", Json::Str(e.to_string())),
                ])),
            }
        }
        let ok = acks >= quorum;
        if !ok {
            self.metrics.record_quorum_fail();
            log::warn!(
                "write for {entity:?} missed quorum: {acks}/{quorum} acks \
                 across {} targets",
                targets.len()
            );
        }
        let mut pairs = vec![
            ("ok", Json::Bool(ok)),
            ("entity", Json::Str(entity.to_string())),
            ("replicas", Json::Num(targets.len() as f64)),
            ("acks", Json::Num(acks as f64)),
            ("applied", Json::Num(applied as f64)),
            ("quorum", Json::Num(quorum as f64)),
        ];
        if !errors.is_empty() {
            pairs.push(("errors", Json::Arr(errors)));
        }
        Json::obj(pairs)
    }
}

/// Stamp the router fields onto a backend (or merged) reply.
fn annotate(reply: Json, backends: usize, degraded: bool) -> Json {
    match reply {
        Json::Obj(mut m) => {
            m.insert("backends".into(), Json::Num(backends as f64));
            m.insert("degraded".into(), Json::Bool(degraded));
            Json::Obj(m)
        }
        other => other,
    }
}

/// An `ok:false` protocol reply, as a walk failure naming the refusing
/// backend (it answered — the coordinator declined — so this does not
/// touch backend health).
fn refusal(backend: &Backend, json: &Json) -> SendFailure {
    SendFailure {
        err: io::Error::other(
            json.get("error")
                .and_then(Json::as_str)
                .unwrap_or("backend refused")
                .to_string(),
        ),
        backend: Some(backend.addr().to_string()),
    }
}

/// The router's terminal failure reply. Carries the address of the last
/// failing backend when one is known, so an `ok:false` is attributable
/// from the client side without router logs.
fn error_reply(f: &SendFailure) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(format!("all backends failed: {}", f.err))),
    ];
    if let Some(addr) = &f.backend {
        pairs.push(("backend", Json::Str(addr.clone())));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_requires_backends() {
        let err = Router::connect(["cardiology"], &RouterConfig::default())
            .expect_err("no backends configured");
        assert!(err.to_string().contains("backend"), "{err}");
    }

    #[test]
    fn annotate_and_error_shapes() {
        let r = annotate(
            Json::obj(vec![("ok", Json::Bool(true))]),
            3,
            true,
        );
        assert_eq!(r.get("backends").and_then(Json::as_f64), Some(3.0));
        assert_eq!(r.get("degraded"), Some(&Json::Bool(true)));

        // the failing backend's address rides along when known...
        let e = error_reply(&SendFailure {
            err: io::Error::other("boom"),
            backend: Some("10.0.0.9:7171".into()),
        });
        assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
        assert!(e
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("boom"));
        assert_eq!(
            e.get("backend").and_then(Json::as_str),
            Some("10.0.0.9:7171"),
            "error replies must name the failing backend"
        );
        // ...and is simply absent when there were no candidates
        let e = error_reply(&SendFailure {
            err: io::Error::other("no backend candidates"),
            backend: None,
        });
        assert!(e.get("backend").is_none());
        // the shape survives a JSON round trip (client-side parsing)
        let back = Json::parse(&e.to_string()).unwrap();
        assert_eq!(back.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn broadcast_rejects_protocol_breaking_entities() {
        let cfg = RouterConfig {
            probe_interval: std::time::Duration::ZERO,
            ..RouterConfig::for_backends(["127.0.0.1:9"])
        };
        let r = Router::connect(["cardiology"], &cfg).unwrap();
        // rejected before any backend is contacted (the fake backend
        // address is never dialed)
        for bad in ["multi\nline", "carriage\rreturn", "\x01stats", ""] {
            let reply = r.update(bad, 0, 0);
            assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{bad:?}");
            assert!(
                reply
                    .get("error")
                    .and_then(Json::as_str)
                    .unwrap()
                    .contains("invalid entity"),
                "{reply}"
            );
            let reply = r.remove(bad);
            assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{bad:?}");
        }
    }

    #[test]
    fn connect_rejects_oversized_replication() {
        let cfg = RouterConfig {
            replication_factor: 3,
            ..RouterConfig::for_backends(["a:1", "b:2"])
        };
        let err = Router::connect(["cardiology"], &cfg)
            .expect_err("R > N must be rejected");
        assert!(err.to_string().contains("replication"), "{err}");
    }
}
