//! Minimal leveled logging (offline replacement for the `log` facade):
//! one line per event to stderr, gated by the `CFT_LOG` env var
//! (`error|warn|info|debug`; default `warn`). Call sites keep the
//! familiar shape — `use crate::util::log;` then `log::info!(...)`.

use std::fmt;
use std::sync::OnceLock;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

fn threshold() -> Level {
    static THRESHOLD: OnceLock<Level> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        match std::env::var("CFT_LOG").as_deref() {
            Ok("error") => Level::Error,
            Ok("info") => Level::Info,
            Ok("debug") => Level::Debug,
            // "warn", unset, or unparseable: the quiet-but-audible default
            _ => Level::Warn,
        }
    })
}

/// True if `level` passes the configured threshold.
pub fn enabled(level: Level) -> bool {
    level <= threshold()
}

/// Emit one log line (used via the level macros, not directly).
pub fn emit(level: Level, args: fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.label(), args);
    }
}

macro_rules! error {
    ($($t:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)*))
    };
}
macro_rules! warn {
    ($($t:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*))
    };
}
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*))
    };
}
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*))
    };
}

// Path-invocable macro re-exports: `log::warn!(...)` after
// `use crate::util::log;`.
pub(crate) use {debug, error, info, warn};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn emit_respects_threshold() {
        // default threshold is warn (CFT_LOG unset in tests): error and
        // warn pass, info and debug are suppressed
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        // every level macro compiles and runs through the emit path,
        // invoked by path exactly as call sites do (`log::warn!`)
        crate::util::log::error!("e {}", 1);
        crate::util::log::warn!("w {}", 2);
        crate::util::log::info!("i {}", 3);
        crate::util::log::debug!("d {}", 4);
    }
}
